"""Online churn race: the open system under low/medium/high traffic.

The ``repro.online`` subsystem runs the SMT cluster as an open queueing
system: Poisson job arrivals, FIFO admission onto 2N hardware contexts,
§6.2 run-to-target execution, departures freeing contexts.  This race
compares, per (cluster size, churn level):

* ``random``        — random pairing, churn patched randomly;
* ``linux``         — sticky CFS-like pairing with occasional migrations;
* ``synpa4-cold``   — the batch SYNPA4 path per quantum (full re-match;
                      N <= COLD_MAX_N unless ``--race-cold-at-full`` asks
                      for the overnight full-size race);
* ``synpa4-stream`` — the fused streaming path (stateless GN inverse +
                      incremental re-matching);
* ``synpa4-stream-syn`` — the same allocator behind queue-aware admission
                      (``ClusterSim(admission="synergy")``): dequeued jobs
                      are placed by predicted co-runner score and the
                      policy receives profiled ST hints for newcomers.
                      The stream-vs-stream-syn cells are the admission A/B.

``--engine scan`` swaps the streaming arm's host matcher for the device
tier (``StreamingConfig(matcher="device")``) in the churn grid, adds a
``synpa4-device`` arm — the whole open system as **one dispatch**
(``ClusterSim(engine="scan")``, ``repro.online.device_sim``) — and adds a
``synpa4-scan`` arm to the static probe — the single-dispatch
``lax.scan`` race of ``repro.smt.scan_engine`` (its machine+policy time is
indivisible; compare it against the probe's cold/stream *sums*).

``--record-device-ab`` records the back-to-back host-vs-device open-system
A/B (medians over rounds, per the 2-CPU jitter protocol) to
``results/device_sim_speedup.json``: total wall per quantum of the whole
loop — policy + machine + bookkeeping — at rho = 1.0, N in {256, 1024}.

``--seeds K`` (default 5) runs every arm over K seeds and reports each
metric as a mean plus a seeded percentile-bootstrap CI
(``repro.smt.metrics.bootstrap_ci``/``GridStats``); metric means stay
top-level floats in every cell, so single-seed readers of the recorded
JSONs keep working, with the intervals under a ``"ci"`` sub-dict.  Under
``--engine scan`` the seed replicas themselves batch: the churn grid's
``synpa4-device`` arm, the probe's ``synpa4-scan`` arm and the fault
sweep's whole profile grid each run *all* their lanes as ONE
``vmap``-batched dispatch (``repro.online.batch_sim``), per-lane
bit-identical to the sequential dispatches they replace.

``--batched`` records the batched-vs-sequential grid A/B
(:func:`record_batched_ab`) to ``results/batched_grid_speedup.json``:
a 12-lane scenario grid (2 rho x 2 admissions x 3 seeds) at N=256 run
once as twelve single dispatches and once as one transfer-guarded
batched dispatch, asserting per-lane f32 bit-identity and recording the
whole-grid wall, the per-scenario cost and the compile-vs-steady split
of both arms.  Under ``--smoke`` the same A/B runs on a tiny unrecorded
grid — the bit-identity smoke arm of ``tools/run_bench_smoke.sh``.

reporting per-job mean/p95 slowdown, turnaround, queue depth and policy
µs/quantum (mean *and* median — the median is the steady-state figure, the
mean amortises one-off jit compilation over the horizon).  Slowdown CCDFs
of every grid cell are recorded to ``results/online_churn_ccdf.json`` on
``--full``/``--race-cold-at-full`` runs (the open-system analogue of the
paper's Fig. 7).  A separate *static-population probe* races the cold and
streaming SYNPA4 paths head-to-head on a closed workload at the largest
sizes (``run_quanta_multi``: one PhaseTables build, bit-identical machine
randomness per policy) — the policy-time speedup headline of the ROADMAP's
"cut the SYNPA per-quantum cost at large N" item.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict

from benchmarks.common import csv_row, get_env, save_stamped

SIZES = (8, 64, 256)          # apps capacity (2 per core); --full adds 1024
FULL_SIZES = (8, 64, 256, 1024)
SMOKE_SIZES = (8, 32)
# Offered utilisation rho (arrival rate / service capacity).  The machine
# always co-schedules two applications per core (paper §6.2 convention, the
# idle-context exception being an odd population), so the regimes where
# pairing quality shows are near and past saturation: low churn still keeps
# most contexts busy, high churn queues jobs faster than they drain.
CHURN = {"low": 0.85, "med": 1.0, "high": 1.2}
COLD_MAX_N = 64               # full cold SYNPA in the churn grid up to here
TARGET_SCALE = 0.25           # shrink §6.2 targets: jobs last ~15 quanta
MEAN_SERVICE_SLOWDOWN = 1.3   # typical SMT slowdown of the service time
# Horizons: jobs last ~15 quanta after admission, so every size must run
# past ~20 quanta for completions (and therefore slowdown CCDFs) to exist.
QUANTA = {8: 80, 32: 60, 64: 60, 256: 30, 1024: 24}
PROBE_QUANTA = 16


def mean_service_quanta(machine) -> float:
    """Expected quanta a job occupies a context: solo quanta under the
    scaled §6.2 target times the typical SMT slowdown.  The rho -> arrival
    rate mapping of every churn cell — shared with the policy budget guard
    (``tools/check_policy_budget.py``) so both always measure the same
    cell."""
    return (machine.params.solo_reference_quanta * TARGET_SCALE
            * MEAN_SERVICE_SLOWDOWN)


def _policies(models, n_apps: int, smoke: bool, cold_max_n: int = COLD_MAX_N,
              engine: str = "vector"):
    from repro.core import isc
    from repro.online import (
        LinuxOnline,
        RandomOnline,
        StreamingAllocator,
        StreamingConfig,
        cold_config,
    )

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    stream_cfg = (
        (lambda: StreamingConfig(matcher="device"))
        if engine == "scan" else (lambda: None)
    )
    pols = {
        "random": lambda: RandomOnline(),
        "linux": lambda: LinuxOnline(),
        "synpa4-stream": lambda: StreamingAllocator(
            method, model, stream_cfg(), name="synpa4-stream"
        ),
        # The queue-aware admission A/B arm: same allocator, synergy
        # admission (the grid loop constructs its ClusterSim with
        # admission="synergy").
        "synpa4-stream-syn": lambda: StreamingAllocator(
            method, model, stream_cfg(), name="synpa4-stream-syn"
        ),
    }
    if n_apps <= cold_max_n and not smoke:
        pols["synpa4-cold"] = lambda: StreamingAllocator(
            method, model, cold_config(), name="synpa4-cold"
        )
    return pols


def _seed_list(base: int, k: int):
    """K well-separated seeds (step 97 keeps the derived streams — seed,
    seed+4242 arrivals, seed+6007 faults, seed+7919 matcher — disjoint
    across replicas); ``base`` first so K=1 reproduces the historical
    single-seed cells bit-for-bit."""
    return [base + 97 * i for i in range(max(1, int(k)))]


def _churn_grid(machine, models, sizes, churn_levels, smoke: bool,
                cold_max_n: int = COLD_MAX_N, record_ccdf: bool = False,
                engine: str = "vector", seeds: int = 1):
    """Open-system races: ClusterSim per (size, churn, policy, seed).

    Returns ``(grid, ccdfs)``; each cell is a ``GridStats`` summary
    (metric means top-level + a ``"ci"`` sub-dict over the seed
    replicas); ``ccdfs`` holds per-cell slowdown CCDFs pooled across
    seeds when ``record_ccdf`` is set (else stays empty).  The
    ``synpa4-device`` arm runs all its seed replicas as ONE batched
    dispatch (``repro.online.batch_sim.run_device_sim_batched``).
    """
    from repro.core import isc
    from repro.online import ClusterSim, PoissonArrivals, SynergyAdmission
    from repro.online.batch_sim import run_device_sim_batched
    from repro.smt.apps import pool_profiles
    from repro.smt.machine import PhaseTables
    from repro.smt.metrics import GridStats, slowdown_ccdf

    pool = pool_profiles()
    tables = PhaseTables.build(pool)   # shared across all grid cells
    synergy = SynergyAdmission(
        machine, pool, isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"]
    )
    device_spec = None
    if engine == "scan":
        from repro.smt.scan_engine import ScanPolicy

        device_spec = ScanPolicy(
            kind="synpa", method=isc.SYNPA4_R_FEBE,
            model=models["SYNPA4_R-FEBE"], name="synpa4-device",
        )
    mean_service_q = mean_service_quanta(machine)
    seed_values = _seed_list(11, seeds)
    grid: Dict[str, Dict] = {}
    ccdfs: Dict[str, Dict] = {}
    for n in sizes:
        n_cores = n // 2
        quanta = QUANTA.get(n, 30) if not smoke else 30
        row: Dict[str, Dict] = {}
        row_ccdf: Dict[str, Dict] = {}
        for level, rho in churn_levels.items():
            rate = rho * n / mean_service_q
            arrivals = PoissonArrivals(rate=rate, n_pool=len(pool))
            gs = GridStats()
            for pname, factory in _policies(
                models, n, smoke, cold_max_n, engine
            ).items():
                adm = (
                    dict(admission="synergy", synergy=synergy)
                    if pname.endswith("-syn") else {}
                )
                for sd in seed_values:
                    sim = ClusterSim(
                        machine, pool, n_cores, factory(), arrivals,
                        seed=sd, target_scale=TARGET_SCALE, tables=tables,
                        **adm,
                    )
                    gs.add(pname, sim.run(quanta))
            if device_spec is not None:
                # The whole open system — every seed replica of the cell
                # — as one batched device dispatch.
                dsims = [
                    ClusterSim(
                        machine, pool, n_cores, device_spec, arrivals,
                        seed=sd, target_scale=TARGET_SCALE, tables=tables,
                        engine="scan",
                    )
                    for sd in seed_values
                ]
                for stats in run_device_sim_batched(dsims, quanta):
                    gs.add("synpa4-device", stats)
            cell = gs.summary()
            if record_ccdf:
                cell_ccdf = {}
                for pname in cell:
                    xs, ys = slowdown_ccdf(gs.pooled_slowdowns(pname))
                    cell_ccdf[pname] = {
                        "slowdown": [float(v) for v in xs],
                        "ccdf": [float(v) for v in ys],
                    }
                row_ccdf[level] = cell_ccdf
            row[level] = cell
        grid[str(n)] = row
        if record_ccdf:
            ccdfs[str(n)] = row_ccdf
    return grid, ccdfs


def _static_probe(machine, models, sizes, smoke: bool,
                  engine: str = "vector", seeds: int = 1) -> Dict:
    """Closed static-population probe: cold vs streaming SYNPA4 policy cost.

    Uses ``run_quanta_multi`` so both policies face bit-identical machine
    randomness off one shared PhaseTables build.  Reports the mean policy
    time (amortising jit compile over the horizon) *and* the median — the
    steady-state per-quantum cost a deployment would pay at 100 ms quanta.
    With ``engine="scan"`` a ``synpa4-scan`` arm joins: the whole race in
    one dispatch, machine+policy time indivisible
    (``scan_total_ms_median``; compare against cold/stream sched+machine).

    ``seeds > 1`` repeats the probe over well-separated seeds and
    reports each key as a mean plus a bootstrap CI (``"ci"`` sub-dict).
    The host arms loop; the scan arm runs *all* its seed lanes as ONE
    batched dispatch (``run_quanta_multi_batched``), so its
    ``scan_total_ms_median`` is the per-scenario share of the fused
    whole-batch wall.
    """
    from repro.core import isc
    from repro.core.synpa import SynpaScheduler
    from repro.online import StreamingScheduler
    from repro.smt import workloads
    from repro.smt.metrics import bootstrap_ci

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    seed_values = _seed_list(3, seeds)
    out: Dict[str, Dict] = {}
    for n in sizes:
        profs = workloads.scaled_workload(n, seed=n)
        quanta = PROBE_QUANTA if not smoke else 4
        per_seed = []
        for sd in seed_values:
            res = machine.run_quanta_multi(
                profs,
                {
                    "synpa4-cold": lambda: SynpaScheduler(method, model),
                    "synpa4-stream":
                        lambda: StreamingScheduler(method, model),
                },
                n_quanta=quanta,
                seed=sd,
            )
            cold, stream = res["synpa4-cold"], res["synpa4-stream"]
            per_seed.append({
                "cold_sched_ms_per_quantum": cold.sched_s_per_quantum * 1e3,
                "stream_sched_ms_per_quantum":
                    stream.sched_s_per_quantum * 1e3,
                "cold_sched_ms_median":
                    cold.sched_s_per_quantum_median * 1e3,
                "stream_sched_ms_median":
                    stream.sched_s_per_quantum_median * 1e3,
                "policy_speedup": cold.sched_s_per_quantum
                / max(stream.sched_s_per_quantum, 1e-12),
                "policy_speedup_median": cold.sched_s_per_quantum_median
                / max(stream.sched_s_per_quantum_median, 1e-12),
                "cold_mean_true_slowdown": cold.mean_true_slowdown,
                "stream_mean_true_slowdown": stream.mean_true_slowdown,
            })
        if engine == "scan":
            from repro.smt.scan_engine import (
                ScanPolicy,
                run_quanta_multi_batched,
            )

            lanes = run_quanta_multi_batched(
                machine, profs,
                {"synpa4-scan": ScanPolicy(
                    kind="synpa", method=method, model=model)},
                seed_values, n_quanta=quanta, repeats=3,
            )["synpa4-scan"]
            for entry, scan in zip(per_seed, lanes):
                entry["scan_total_ms_median"] = (
                    scan.machine_s_per_quantum * 1e3
                )
                entry["scan_mean_true_slowdown"] = scan.mean_true_slowdown
        cell: Dict[str, object] = {}
        ci: Dict[str, list] = {}
        for k in per_seed[0]:
            point, lo, hi = bootstrap_ci([d[k] for d in per_seed])
            cell[k] = point
            ci[k] = [lo, hi]
        cell["ci"] = ci
        cell["seeds"] = len(per_seed)
        out[str(n)] = cell
    return out


def _fault_profiles(n_cores: int, quanta: int) -> Dict[str, object]:
    """The fault-profile grid, scaled to the cell: a crash wave taking an
    eighth of the cores down mid-run (staggered recoveries), geometric
    MTTF/MTTR churn, a straggler band at half speed, and the kitchen-sink
    combination.  ``None`` is the faults-off control arm every slowdown
    is normalised against."""
    from repro.online import FaultProfile

    k = max(1, n_cores // 8)
    down_q, up_q = quanta // 4, (3 * quanta) // 4
    crash = tuple((down_q + i % 3, i) for i in range(k))
    heal = tuple((up_q + i % 3, i) for i in range(k))
    band = tuple(
        (c, quanta // 3, (2 * quanta) // 3, 0.5)
        for c in range(n_cores - max(1, n_cores // 8), n_cores)
    )
    return {
        "none": None,
        "crash-wave": FaultProfile(fail=crash, recover=heal),
        "mttf-churn": FaultProfile(mttf_quanta=3.0 * quanta,
                                   mttr_quanta=quanta / 6.0),
        "stragglers": FaultProfile(straggle=band),
        "combined": FaultProfile(fail=crash, recover=heal, straggle=band,
                                 mttf_quanta=6.0 * quanta,
                                 mttr_quanta=quanta / 6.0),
    }


def fault_grid(machine, models, sizes, smoke: bool,
               engine: str = "vector", seeds: int = 1) -> Dict:
    """Graceful-degradation sweep: the rho=1.0 churn cell per size, re-run
    under each fault profile (both engines share the schedule bit-for-bit,
    so either engine measures the same faults).  Per cell: the GridStats
    summary over the seed replicas (means + bootstrap CIs), the slowdown
    CCDF and retry CCDF pooled across seeds, and the degradation ratio
    (mean slowdown vs the faults-off control arm of the same cell).

    Under ``engine="scan"`` the *entire* per-size grid — every (fault
    profile, seed) combination, faults-off control included — runs as
    ONE batched device dispatch: divergent per-lane fault schedules and
    retry knobs are data, not structure (``repro.online.batch_sim``).
    """
    import numpy as np

    from repro.core import isc
    from repro.online import ClusterSim, PoissonArrivals, StreamingAllocator
    from repro.online.batch_sim import run_device_sim_batched
    from repro.smt.apps import pool_profiles
    from repro.smt.machine import PhaseTables
    from repro.smt.metrics import GridStats, slowdown_ccdf
    from repro.smt.scan_engine import ScanPolicy

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    pool = pool_profiles()
    tables = PhaseTables.build(pool)
    mean_service_q = mean_service_quanta(machine)
    seed_values = _seed_list(11, seeds)
    out: Dict[str, Dict] = {}
    for n in sizes:
        n_cores = n // 2
        quanta = QUANTA.get(n, 30) if not smoke else 30
        arrivals = PoissonArrivals(
            rate=CHURN["med"] * n / mean_service_q, n_pool=len(pool)
        )
        profiles = _fault_profiles(n_cores, quanta)
        gs = GridStats()
        if engine == "scan":
            lane_sims, lane_names = [], []
            for fname, fp in profiles.items():
                for sd in seed_values:
                    policy = ScanPolicy(kind="synpa", method=method,
                                        model=model, name="synpa4-device")
                    lane_sims.append(ClusterSim(
                        machine, pool, n_cores, policy, arrivals,
                        seed=sd, target_scale=TARGET_SCALE, tables=tables,
                        faults=fp, engine="scan",
                    ))
                    lane_names.append(fname)
            for fname, stats in zip(
                lane_names, run_device_sim_batched(lane_sims, quanta)
            ):
                gs.add(fname, stats)
        else:
            for fname, fp in profiles.items():
                for sd in seed_values:
                    policy = StreamingAllocator(method, model,
                                                name="synpa4-stream")
                    sim = ClusterSim(
                        machine, pool, n_cores, policy, arrivals,
                        seed=sd, target_scale=TARGET_SCALE, tables=tables,
                        faults=fp,
                    )
                    gs.add(fname, sim.run(quanta))
        summ = gs.summary()
        row: Dict[str, Dict] = {}
        base_slowdown = None
        for fname, fp in profiles.items():
            cell = summ[fname]
            xs, ys = slowdown_ccdf(gs.pooled_slowdowns(fname))
            cell["slowdown_ccdf"] = {
                "slowdown": [float(v) for v in xs],
                "ccdf": [float(v) for v in ys],
            }
            if fp is not None:
                # Retry CCDF pooled over the seed replicas (the per-run
                # version is OnlineStats.retry_ccdf).
                r = np.concatenate([
                    np.asarray([j.retries for j in st.completed], np.int64)
                    for st in gs.cells[fname]
                ]) if gs.cells.get(fname) else np.zeros(0, np.int64)
                hi = int(r.max()) if r.size else 0
                grid_r = np.arange(hi + 1, dtype=np.float64)
                ccdf_r = ((r[None, :] > grid_r[:, None]).mean(axis=1)
                          if r.size else np.zeros_like(grid_r))
                cell["retry_ccdf"] = {
                    "retries": [int(v) for v in grid_r],
                    "ccdf": [float(v) for v in ccdf_r],
                }
            if fname == "none":
                base_slowdown = cell["mean_slowdown"]
            cell["degradation_x"] = (
                cell["mean_slowdown"] / max(base_slowdown, 1e-12)
            )
            row[fname] = cell
        out[str(n)] = row
    return out


def record_device_ab(machine, models, sizes=(256, 1024), rho: float = 1.0,
                     rounds: int = 5) -> Dict:
    """Back-to-back host-vs-device open-system A/B; medians recorded.

    Per size: both arms run the identical rho-churn cell (same seed, same
    pre-sampled traffic) and both are timed the same way — whole-run wall
    per quantum over ``rounds`` back-to-back runs, everything the tier
    needs per run inside the timer.  For the host arm (the PR 4 path:
    ``ClusterSim`` event loop + ``StreamingAllocator``, fused dispatch +
    host matcher) that is arrival sampling, the Python loop and the stats
    build; for the device arm it is the arrival pre-sample, host->device
    commits, exactly one dispatch of the compiled race (``warmup=False``)
    and the job-log fetch + ``JobRecord`` rebuild.  One policy/compiled
    race serves all rounds of an arm, so the median sheds the
    jit-compile round of each.  Total per-quantum wall — policy +
    machine + bookkeeping, the only figure comparable across the tiers —
    lands in ``results/device_sim_speedup.json`` with both arms' per-job
    quality.
    """
    import numpy as np

    from repro.core import isc
    from repro.online import ClusterSim, PoissonArrivals, StreamingAllocator
    from repro.online.device_sim import run_device_sim
    from repro.smt.apps import pool_profiles
    from repro.smt.machine import PhaseTables
    from repro.smt.scan_engine import ScanPolicy

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    pool = pool_profiles()
    tables = PhaseTables.build(pool)
    mean_service_q = mean_service_quanta(machine)
    out: Dict[str, Dict] = {
        "protocol": f"back-to-back whole-run medians, {rounds} rounds "
                    "per arm",
        "rho": rho,
    }
    host_policy = StreamingAllocator(method, model, name="synpa4-stream")
    device_spec = ScanPolicy(kind="synpa", method=method, model=model,
                             name="synpa4-device")
    for n in sizes:
        quanta = QUANTA.get(n, 30)
        arrivals = PoissonArrivals(rate=rho * n / mean_service_q,
                                   n_pool=len(pool))
        host_walls = []
        hs = None
        for _ in range(rounds):
            sim = ClusterSim(
                machine, pool, n // 2, host_policy, arrivals,
                seed=11, target_scale=TARGET_SCALE, tables=tables,
            )
            t0 = time.perf_counter()
            hs = sim.run(quanta)
            host_walls.append((time.perf_counter() - t0) / quanta)
        dev = ClusterSim(
            machine, pool, n // 2, device_spec, arrivals,
            seed=11, target_scale=TARGET_SCALE, tables=tables,
            engine="scan",
        )
        dev_walls = []
        ds = None
        for _ in range(rounds):
            t0 = time.perf_counter()
            ds = run_device_sim(dev, quanta, warmup=False)
            dev_walls.append((time.perf_counter() - t0) / quanta)
        host_ms = float(np.median(host_walls)) * 1e3
        dev_ms = float(np.median(dev_walls)) * 1e3
        out[str(n)] = {
            "quanta": quanta,
            "host_ms_per_quantum_median": host_ms,
            "device_ms_per_quantum_median": dev_ms,
            "speedup": host_ms / max(dev_ms, 1e-9),
            "host_mean_slowdown": hs.mean_slowdown,
            "device_mean_slowdown": ds.mean_slowdown,
            "host_n_completed": hs.n_completed,
            "device_n_completed": ds.n_completed,
        }
    save_stamped("device_sim_speedup.json", out, engine="device")
    return out


def _lanes_bit_identical(a, b) -> bool:
    """True when two OnlineStats describe the exact same run — f32
    bit-identity, the batched-scenario contract: same per-quantum
    queue-depth/occupancy trajectories and identical completed-job logs
    (admit/finish quanta compare ``==``, not approximately)."""
    import numpy as np

    if not (np.array_equal(a.queue_depth, b.queue_depth)
            and np.array_equal(a.active, b.active)):
        return False
    ja = {j.job_id: (j.arrive_q, j.admit_q, j.finish_q, j.retries)
          for j in a.completed}
    jb = {j.job_id: (j.arrive_q, j.admit_q, j.finish_q, j.retries)
          for j in b.completed}
    return ja == jb


def record_batched_ab(machine, models, n: int = 256,
                      rhos=(0.85, 1.2), admissions=("fifo", "synergy"),
                      seeds=(11, 108, 205), rounds: int = 4,
                      quanta: int = None, record: bool = True) -> Dict:
    """Batched-vs-sequential grid A/B: the whole scenario grid
    (rho x admission x seed) on the device tier, once as ``len(sims)``
    single dispatches (``run_device_sim`` in a loop) and once as ONE
    ``vmap``-batched, transfer-guarded dispatch
    (``repro.online.batch_sim.run_device_sim_batched``).

    Both arms are timed the same way: whole-grid wall per round with
    everything inside the timer (arrival pre-sample, host->device
    commits, dispatch, job-log fetch + stats rebuild; ``warmup=False``).
    The arms are *interleaved* — each round times both grids, in an
    order that alternates per round — so slow drift on a shared box
    (thermal, noisy neighbours) lands on both arms instead of biasing
    whichever block ran second, and within-round allocator/cache
    carry-over is counterbalanced rather than one-sided.  Round 0 of each arm carries its jit compile; the
    steady figure is the median of the remaining rounds and the
    compile-vs-steady split is recorded per arm.  The sequential arm
    additionally times each lane, giving a true per-lane breakdown; the
    batched arm's per-lane cost is by construction the uniform 1/L
    share of the fused wall.  The two arms live under an ``"arms"``
    sub-dict in the result — top-level would collide with the
    ``batched``/``lanes`` stamp keys, which ``save_stamped`` refuses.

    Two per-scenario figures are recorded per arm: *steady* (median of
    the warm rounds — what repeat invocations pay once the persistent
    compile cache is hot) and *one-shot* (round 0, compile included —
    what a fresh container or a not-yet-cached config pays).  On a
    single-CPU box the steady figures are close to parity: the batched
    graph amortizes dispatch and wrapper overheads but pays the union
    of both admission rules' work in every lane plus max-over-lanes
    trip counts in the dynamic loops (vmap's ``while_loop`` rule),
    while the one-shot figure favours the batched arm outright — one
    compile instead of one per admission rule.

    Every batched lane is asserted f32-bit-identical to its sequential
    twin before anything is recorded (the file carries
    ``lanes_bit_identical`` as witness).  Results land in
    ``results/batched_grid_speedup.json`` stamped ``batched=True`` +
    lane count, refusing silent comparison against single-lane
    recordings.  ``record=False`` runs the same protocol unrecorded —
    the ``--smoke --batched`` bit-identity arm.
    """
    import numpy as np

    from repro.core import isc
    from repro.online import ClusterSim, PoissonArrivals, SynergyAdmission
    from repro.online.batch_sim import run_device_sim_batched
    from repro.online.device_sim import run_device_sim
    from repro.smt.apps import pool_profiles
    from repro.smt.machine import PhaseTables
    from repro.smt.metrics import bootstrap_ci
    from repro.smt.scan_engine import ScanPolicy

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    pool = pool_profiles()
    tables = PhaseTables.build(pool)
    synergy = SynergyAdmission(machine, pool, method, model)
    mean_service_q = mean_service_quanta(machine)
    quanta = quanta if quanta is not None else QUANTA.get(n, 30)
    spec = ScanPolicy(kind="synpa", method=method, model=model,
                      name="synpa4-device")
    sims, labels = [], []
    for rho in rhos:
        arrivals = PoissonArrivals(rate=rho * n / mean_service_q,
                                   n_pool=len(pool))
        for adm in admissions:
            kw = (dict(admission="synergy", synergy=synergy)
                  if adm == "synergy" else {})
            for sd in seeds:
                sims.append(ClusterSim(
                    machine, pool, n // 2, spec, arrivals,
                    seed=sd, target_scale=TARGET_SCALE, tables=tables,
                    engine="scan", **kw,
                ))
                labels.append(f"rho={rho}/{adm}/seed={sd}")
    L = len(sims)
    rounds = max(2, rounds)

    seq_walls, seq_lane_walls, seq_stats = [], [], None
    bat_walls, bat_stats = [], None

    def run_seq():
        nonlocal seq_stats
        lane_walls = []
        t0 = time.perf_counter()
        stats = []
        for s in sims:
            t1 = time.perf_counter()
            stats.append(run_device_sim(s, quanta, warmup=False))
            lane_walls.append(time.perf_counter() - t1)
        seq_walls.append(time.perf_counter() - t0)
        seq_lane_walls.append(lane_walls)
        seq_stats = stats

    def run_bat():
        nonlocal bat_stats
        t0 = time.perf_counter()
        bat_stats = run_device_sim_batched(
            sims, quanta, transfer_guard=True, warmup=False
        )
        bat_walls.append(time.perf_counter() - t0)

    for r in range(rounds):
        # Counterbalanced order: odd rounds run the batched arm first,
        # so allocator/cache state left by one arm lands on both arms
        # equally instead of always penalizing whichever runs second.
        first, second = (run_seq, run_bat) if r % 2 == 0 else (
            run_bat, run_seq)
        first()
        second()

    identical = all(
        _lanes_bit_identical(a, b) for a, b in zip(bat_stats, seq_stats)
    )
    assert identical, (
        "batched lanes diverged from their sequential twins — the "
        "bit-identity contract of repro.online.batch_sim is broken"
    )

    seq_steady = float(np.median(seq_walls[1:]))
    bat_steady = float(np.median(bat_walls[1:]))
    lane_steady = np.median(np.asarray(seq_lane_walls[1:]), axis=0)
    per_lane = []
    for i, lab in enumerate(labels):
        st = bat_stats[i]
        per_lane.append({
            "lane": lab,
            "mean_slowdown": st.mean_slowdown,
            "n_completed": st.n_completed,
            "sequential_ms": float(lane_steady[i]) * 1e3,
            "batched_ms_share": bat_steady / L * 1e3,
        })
    # Cross-seed aggregation per (rho, admission) scenario — the CI the
    # lane-batched exports carry.
    cells: Dict[str, Dict] = {}
    for rho in rhos:
        for adm in admissions:
            key = f"rho={rho}/{adm}"
            vals = [bat_stats[i].mean_slowdown
                    for i, lab in enumerate(labels)
                    if lab.startswith(key + "/")]
            point, lo, hi = bootstrap_ci(vals)
            cells[key] = {"mean_slowdown": point, "ci": [lo, hi],
                          "seeds": len(vals)}
    out = {
        "protocol": f"whole-grid wall per round, {rounds} interleaved "
                    "rounds (sequential then batched each round; round 0 "
                    "= compile), steady = median of the rest; "
                    "warmup=False, batched arm transfer-guarded",
        "n": n, "quanta": quanta,
        "grid": {"rhos": list(rhos), "admissions": list(admissions),
                 "seeds": [int(s) for s in seeds]},
        "lanes_bit_identical": identical,
        "arms": {
            "sequential": {
                "whole_grid_walls_s": [float(w) for w in seq_walls],
                "whole_grid_steady_s": seq_steady,
                "whole_grid_one_shot_s": float(seq_walls[0]),
                "per_scenario_ms": seq_steady / L * 1e3,
                "per_scenario_ms_one_shot": float(seq_walls[0]) / L * 1e3,
                "per_scenario_ms_per_quantum":
                    seq_steady / (L * quanta) * 1e3,
                "compile_s": float(seq_walls[0]) - seq_steady,
            },
            "batched": {
                "whole_grid_walls_s": [float(w) for w in bat_walls],
                "whole_grid_steady_s": bat_steady,
                "whole_grid_one_shot_s": float(bat_walls[0]),
                "per_scenario_ms": bat_steady / L * 1e3,
                "per_scenario_ms_one_shot": float(bat_walls[0]) / L * 1e3,
                "per_scenario_ms_per_quantum":
                    bat_steady / (L * quanta) * 1e3,
                "compile_s": float(bat_walls[0]) - bat_steady,
            },
        },
        "speedup_per_scenario": seq_steady / max(bat_steady, 1e-9),
        # Round 0 of each arm: compile + dispatch + stats, the cost a
        # fresh process (or a config not yet in the persistent compile
        # cache) pays for the whole grid once — the batched arm compiles
        # ONE program where the loop compiles one per admission rule.
        "speedup_one_shot":
            float(seq_walls[0]) / max(float(bat_walls[0]), 1e-9),
        "per_lane": per_lane,
        "cells": cells,
    }
    if record:
        save_stamped("batched_grid_speedup.json", out, engine="device",
                     batched=True, lanes=L)
    return out


def main(smoke: bool = False, full: bool = False, quick: bool = False,
         race_cold_at_full: bool = False, engine: str = "vector",
         device_ab: bool = False, faults: bool = False,
         seeds: int = 5, batched: bool = False) -> str:
    machine, models, _wls = get_env(fast=smoke)
    t_total = time.perf_counter()
    cold_max_n = max(FULL_SIZES) if race_cold_at_full else COLD_MAX_N
    full = full or race_cold_at_full
    if smoke:
        sizes, churn = SMOKE_SIZES, {"med": CHURN["med"]}
        probe_sizes = (32,)
        seeds = min(seeds, 2)   # keep the sanity tier sub-minute
    elif quick:
        sizes, churn = (8, 64), CHURN
        probe_sizes = (64,)
    else:
        sizes = FULL_SIZES if full else SIZES
        churn = CHURN
        probe_sizes = tuple(n for n in sizes if n >= 256) or (max(sizes),)
    record_ccdf = full and not smoke
    grid, ccdfs = _churn_grid(
        machine, models, sizes, churn, smoke,
        cold_max_n=cold_max_n, record_ccdf=record_ccdf, engine=engine,
        seeds=seeds,
    )
    probe = _static_probe(machine, models, probe_sizes, smoke,
                          engine=engine, seeds=seeds)
    results = {"churn": grid, "static_probe": probe,
               "target_scale": TARGET_SCALE,
               "seeds": seeds,
               "race_cold_at_full": race_cold_at_full}
    if not smoke:
        # The smoke tier is a sanity run on a sub-real grid; keep it from
        # overwriting recorded results (mirrors cluster_scale.py).  Saved
        # results carry the engine + RNG stream version stamps so a later
        # comparison can refuse them on mismatch (benchmarks.common).
        save_stamped("online_churn.json"
                     if engine == "vector" else "online_churn_scan.json",
                     results, engine=engine)
    if record_ccdf:
        # Engine-gated like the grid file: a scan run must not overwrite
        # the recorded vector-engine CCDFs (different RNG trajectories).
        save_stamped("online_churn_ccdf.json"
                     if engine == "vector" else "online_churn_ccdf_scan.json",
                     ccdfs, engine=engine)
    if faults:
        fg = fault_grid(machine, models, sizes, smoke, engine=engine,
                        seeds=seeds)
        if not smoke:
            # Fault results are additionally tied to the fault-schedule
            # stream version (``faults=True`` stamps it).
            save_stamped("online_churn_faults.json"
                         if engine == "vector"
                         else "online_churn_faults_scan.json",
                         fg, engine=engine, faults=True)
        n_f = str(max(int(k) for k in fg))
        for fname, cell in fg[n_f].items():
            print(f"# faults N={n_f} {fname}: "
                  f"degradation {cell['degradation_x']:.2f}x, "
                  f"evicted {cell.get('n_evicted', 0):.0f}, "
                  f"requeued {cell.get('n_requeued', 0):.0f}, "
                  f"dropped {cell.get('n_dropped', 0):.0f}")
    if device_ab and smoke:
        print("# --record-device-ab ignored under --smoke: the recorded "
              "A/B is a full-size fitted-model measurement")
        device_ab = False
    if device_ab:
        ab = record_device_ab(machine, models)
        for n in (k for k in ab if k.isdigit()):
            print(f"# device A/B N={n}: {ab[n]['speedup']:.2f}x "
                  f"({ab[n]['host_ms_per_quantum_median']:.1f} -> "
                  f"{ab[n]['device_ms_per_quantum_median']:.1f} ms/quantum)")
    if batched:
        if smoke:
            # Tiny unrecorded grid: exercises the whole batched protocol
            # (transfer guard + bit-identity assert) in seconds.
            bab = record_batched_ab(
                machine, models, n=16, seeds=(11, 108), rounds=2,
                quanta=12, record=False,
            )
        else:
            bab = record_batched_ab(machine, models)
        seq_arm, bat_arm = bab["arms"]["sequential"], bab["arms"]["batched"]
        print(f"# batched grid N={bab['n']} ({len(bab['per_lane'])} lanes): "
              f"{bab['speedup_per_scenario']:.2f}x per-scenario steady "
              f"({seq_arm['per_scenario_ms']:.1f} -> "
              f"{bat_arm['per_scenario_ms']:.1f} ms), "
              f"{bab['speedup_one_shot']:.2f}x one-shot "
              f"(compile {seq_arm['compile_s']:.1f}s seq / "
              f"{bat_arm['compile_s']:.1f}s batched), "
              f"bit-identical={bab['lanes_bit_identical']}")

    big = str(max(int(k) for k in probe))
    # Headline slowdown gain: the largest size whose horizon produced
    # completed jobs (per-job slowdown needs completions to exist).
    n_big = str(max(
        (int(k) for k, row in grid.items()
         if all(c["n_completed"] > 0 for lv in row.values()
                for c in lv.values())),
        default=max(int(k) for k in grid),
    ))
    level = "med" if "med" in grid[n_big] else next(iter(grid[n_big]))
    cell = grid[n_big][level]
    gain = (
        cell["random"]["mean_slowdown"]
        / max(cell["synpa4-stream"]["mean_slowdown"], 1e-12)
    )
    us = (time.perf_counter() - t_total) * 1e6
    return csv_row(
        "online_churn", us,
        f"N={big} stream policy speedup {probe[big]['policy_speedup']:.1f}x "
        f"mean / {probe[big]['policy_speedup_median']:.1f}x steady vs cold "
        f"(slowdown {probe[big]['stream_mean_true_slowdown']:.3f} vs "
        f"{probe[big]['cold_mean_true_slowdown']:.3f}); "
        f"N={n_big} {level}-churn slowdown gain {gain:.2f}x vs random",
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="sub-minute sanity run (small N, fast models)")
    ap.add_argument("--full", action="store_true",
                    help="include N=1024 in the churn grid")
    ap.add_argument("--quick", action="store_true",
                    help="cap the grid at N=64 (the benchmarks.run tier)")
    ap.add_argument("--race-cold-at-full", action="store_true",
                    help="race the synpa4-cold arm at every size of the "
                    "--full grid (N=1024 included) instead of probe sizes "
                    "only — the overnight run; implies --full and records "
                    "the CCDF figures")
    ap.add_argument("--engine", choices=("vector", "scan"),
                    default="vector",
                    help="scan: device matcher in the streaming arm, a "
                    "one-dispatch synpa4-device arm in the churn grid and "
                    "a single-dispatch synpa4-scan arm in the static probe")
    ap.add_argument("--record-device-ab", action="store_true",
                    help="record the back-to-back host-vs-device "
                    "open-system A/B (medians) to "
                    "results/device_sim_speedup.json")
    ap.add_argument("--faults", action="store_true",
                    help="add the graceful-degradation sweep: the rho=1.0 "
                    "cell per size under a fault-profile grid (crash wave, "
                    "MTTF/MTTR churn, stragglers, combined), recording "
                    "per-profile slowdown + requeue CCDFs and degradation "
                    "ratios to results/online_churn_faults*.json")
    ap.add_argument("--seeds", type=int, default=5, metavar="K",
                    help="seed replicas per arm (default 5; --smoke caps "
                    "at 2): every metric becomes a mean + bootstrap CI, "
                    "and under --engine scan the replicas run as one "
                    "batched dispatch")
    ap.add_argument("--batched", action="store_true",
                    help="run the batched-vs-sequential grid A/B "
                    "(bit-identity asserted, batched arm transfer-"
                    "guarded); records results/batched_grid_speedup.json "
                    "unless --smoke, which runs a tiny unrecorded grid")
    args = ap.parse_args()
    print(main(smoke=args.smoke, full=args.full, quick=args.quick,
               race_cold_at_full=args.race_cold_at_full,
               engine=args.engine, device_ab=args.record_device_ab,
               faults=args.faults, seeds=args.seeds,
               batched=args.batched))
