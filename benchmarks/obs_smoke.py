"""Observability smoke run: telemetry-on runs of both scan engines,
exported as one ``repro.obs`` run report.

Drives the whole ``repro.obs`` stack end to end on a small grid:

* the open system (``ClusterSim(engine="scan")``) with the device
  telemetry ring enabled — per-quantum queue/active/slowdown/GN
  counters recorded in-graph, one dispatch, zero extra transfers;
* the closed scan race (``run_quanta_multi(engine="scan")``) with its
  ring enabled;
* host span tracing (``repro.obs.trace``) around both, captured into
  the export's ``spans`` block.

Both engines run with the per-app rings on (``app_telemetry=True``),
and the export carries per-arm ``accuracy`` blocks
(``repro.obs.accuracy.accuracy_report``: per-app/per-pair MAPE stacks,
error CCDF, drift windows) plus their flat scalars (``open_acc_mape``
etc.) in the metrics table — so the baseline diff pins prediction
accuracy with the same 5% tolerance as the other deterministic
metrics, and a model/policy change that degrades Eq.4 error fails the
smoke.  The raw rings stay out of the export (the accuracy block is
the aggregated view) to keep it light.

The live export lands in the *untracked* ``results/smoke/`` directory —
re-running the smoke tier must leave the working tree clean —
while ``--record`` writes the tracked baseline copy
(``results/obs_smoke_baseline.json``) the smoke tier diffs against:
non-timing metrics are deterministic given the RNG stream stamps, so
any drift there is a real behaviour change, while wall-time metrics
get the usual 2x jitter budget.

Run via ``tools/run_bench_smoke.sh`` (slow-marked tier-1).
"""

from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import RESULTS_DIR, get_env  # noqa: E402

N_APPS = 32          # closed-race population
N_CORES = 8          # open-system capacity: 16 contexts
N_QUANTA = 40
#: Untracked smoke-tier output directory: live exports churn on every
#: run, so they must never live next to the tracked baselines.
SMOKE_DIR = os.path.join(RESULTS_DIR, "smoke")
EXPORT = os.path.join(SMOKE_DIR, "obs_smoke.json")
BASELINE = os.path.join(RESULTS_DIR, "obs_smoke_baseline.json")


def run_export():
    """One telemetry-on pass of both engines -> a run export dict."""
    from repro.core import isc
    from repro.obs import accuracy as obs_accuracy
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.online import ClusterSim, PoissonArrivals
    from repro.smt import workloads
    from repro.smt.apps import pool_profiles
    from repro.smt.scan_engine import ScanPolicy

    machine, models, _ = get_env(fast=True)
    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    pool = pool_profiles()
    spec = ScanPolicy(kind="synpa", method=method, model=model)

    obs_trace.clear()
    obs_trace.enable()
    try:
        with obs_trace.span("obs_smoke.open"):
            sim = ClusterSim(
                machine, pool, N_CORES, spec,
                PoissonArrivals(rate=1.5, n_pool=len(pool)),
                seed=13, target_scale=0.1, engine="scan",
            )
            stats = sim.run(N_QUANTA, telemetry=True, app_telemetry=True)
        with obs_trace.span("obs_smoke.closed"):
            profs = workloads.scaled_workload(N_APPS, seed=N_APPS)
            res = machine.run_quanta_multi(
                profs, {"synpa4-scan": spec}, n_quanta=N_QUANTA, seed=3,
                engine="scan", telemetry=True, app_telemetry=True,
            )["synpa4-scan"]
    finally:
        obs_trace.disable()

    accuracy = {
        "open": obs_accuracy.accuracy_report(stats.app_telemetry),
        "closed": obs_accuracy.accuracy_report(res.app_telemetry),
    }
    metrics = {
        **obs_metrics.stats_metrics(stats, prefix="open_"),
        **{f"open_{k}": v for k, v in stats.telemetry.summary().items()},
        **obs_metrics.throughput_metrics(res, prefix="closed_"),
        **{f"closed_{k}": v for k, v in res.telemetry.summary().items()},
        **obs_accuracy.report_metrics(accuracy["open"], prefix="open_"),
        **obs_accuracy.report_metrics(accuracy["closed"],
                                      prefix="closed_"),
    }
    timelines = {f"open_{k}": v for k, v in stats.timelines().items()
                 if not k.startswith("tlm_")}
    return obs_metrics.export_run(
        name="obs_smoke",
        engine="scan",
        metrics=metrics,
        timelines=timelines,
        telemetry={"open": stats.telemetry, "closed": res.telemetry},
        accuracy=accuracy,
        spans=obs_trace.events(),
        meta={"n_apps": N_APPS, "n_cores": N_CORES, "quanta": N_QUANTA},
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for smoke-runner symmetry (this "
                         "benchmark is already smoke-sized)")
    ap.add_argument("--record", action="store_true",
                    help="also write the baseline the smoke tier diffs "
                         "against")
    args = ap.parse_args()

    from repro.obs import metrics as obs_metrics

    run = run_export()
    os.makedirs(SMOKE_DIR, exist_ok=True)
    obs_metrics.save_run(EXPORT, run)
    print(f"# wrote {EXPORT}")
    if args.record:
        obs_metrics.save_run(BASELINE, run)
        print(f"# wrote {BASELINE}")
    n_tlm = len(run.get("telemetry", {}))
    print(f"obs_smoke: {len(run['metrics'])} metrics, "
          f"{len(run.get('timelines', {}))} timelines, "
          f"{n_tlm} telemetry rings, {len(run.get('spans', []))} spans")
    return 0


if __name__ == "__main__":
    sys.exit(main())
