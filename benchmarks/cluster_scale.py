"""Cluster-scale race: Linux / random-static / SYNPA4 at N in {8..1024}.

The paper evaluates 8 applications on 4 SMT cores; the north-star is a
scheduler that re-pairs *cluster-sized* populations every quantum.  This
scenario runs the fixed-horizon throughput mode of the vectorised machine at
N = 8, 64, 256 and 1024 apps and reports, per policy:

* ground-truth mean slowdown of the chosen pairings (the quality signal),
* machine-wide IPC geomean,
* policy wall-time per quantum (pipeline + matcher cost at scale),
* simulator wall-time per quantum.

It also measures the vectorised machine against the per-app reference loop
at N = 256 (same seeds, bit-identical results) to keep the speedup honest.
"""

from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import csv_row, get_env, save_json

SIZES = (8, 64, 256, 1024)
QUANTA = {8: 40, 64: 30, 256: 20, 1024: 8}


def _policies(models):
    from repro.core import isc
    from repro.core.baselines import LinuxScheduler, RandomStaticScheduler
    from repro.core.synpa import SynpaScheduler

    return {
        "linux": lambda: LinuxScheduler(),
        "random": lambda: RandomStaticScheduler(),
        "synpa4": lambda: SynpaScheduler(
            isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"]
        ),
    }


def _engine_speedup(machine, n: int = 256, quanta: int = 30) -> float:
    """Wall-clock ratio loop/vector for one fixed workload (bit-identical)."""
    from repro.core.baselines import RandomStaticScheduler
    from repro.smt import workloads

    profs = workloads.scaled_workload(n, seed=n)
    t0 = time.perf_counter()
    machine.run_workload(profs, RandomStaticScheduler(), seed=1,
                         max_quanta=quanta, engine="loop")
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    machine.run_workload(profs, RandomStaticScheduler(), seed=1,
                         max_quanta=quanta, engine="vector")
    t_vec = time.perf_counter() - t0
    return t_loop / max(t_vec, 1e-9)


def main(quick: bool = False, smoke: bool = False) -> str:
    from repro.smt import workloads

    machine, models, _wls = get_env(fast=smoke)
    if smoke:
        sizes = [8, 32]
    else:
        sizes = [n for n in SIZES if n <= (256 if quick else 1024)]
    results: Dict[str, Dict] = {}
    t_total = time.perf_counter()
    for n in sizes:
        profs = workloads.scaled_workload(n, seed=n)
        quanta = QUANTA.get(n, 8)
        if quick or smoke:
            quanta = max(quanta // 2, 4)
        # One PhaseTables build, K policies, bit-identical machine stream.
        multi = machine.run_quanta_multi(
            profs, _policies(models), n_quanta=quanta, seed=3
        )
        results[str(n)] = {
            pname: {
                "mean_true_slowdown": res.mean_true_slowdown,
                "ipc_geomean": res.ipc_geomean,
                "sched_ms_per_quantum": res.sched_s_per_quantum * 1e3,
                "sched_ms_median": res.sched_s_per_quantum_median * 1e3,
                "machine_ms_per_quantum": res.machine_s_per_quantum * 1e3,
            }
            for pname, res in multi.items()
        }
    if not smoke:
        speedup = _engine_speedup(machine, n=256, quanta=30)
        results["engine_speedup_n256"] = speedup
        save_json("cluster_scale.json", results)
    else:
        speedup = float("nan")

    # Headline: slowdown win of SYNPA4 over Linux at the largest N raced.
    big = results[str(sizes[-1])]
    gain = big["linux"]["mean_true_slowdown"] / big["synpa4"]["mean_true_slowdown"]
    us = (time.perf_counter() - t_total) * 1e6
    return csv_row(
        "cluster_scale", us,
        f"N={sizes[-1]} synpa4 slowdown gain {gain:.3f}x vs linux; "
        f"vector engine {speedup:.1f}x vs loop at N=256",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="cap at N=256 with halved horizons")
    ap.add_argument("--smoke", action="store_true",
                    help="sub-minute sanity run (small N, fast models, "
                    "no JSON/engine-speedup refresh)")
    args = ap.parse_args()
    print(main(quick=args.quick, smoke=args.smoke))
