"""Cluster-scale race: Linux / random-static / SYNPA4 at N in {8..1024}.

The paper evaluates 8 applications on 4 SMT cores; the north-star is a
scheduler that re-pairs *cluster-sized* populations every quantum.  This
scenario runs the fixed-horizon throughput mode of the vectorised machine at
N = 8, 64, 256 and 1024 apps and reports, per policy:

* ground-truth mean slowdown of the chosen pairings (the quality signal),
* machine-wide IPC geomean,
* policy wall-time per quantum (pipeline + matcher cost at scale),
* simulator wall-time per quantum.

It also measures the vectorised machine against the per-app reference loop
at N = 256 (same seeds, bit-identical results) to keep the speedup honest.

``--engine scan`` races the same policy line-up through the
accelerator-resident engine (``repro.smt.scan_engine``): machine quantum,
fused SYNPA step and device matcher composed into one ``lax.scan`` — one
dispatch per race, per-quantum wall time indivisible (reported as
``total_ms_per_quantum``).  ``--record-scan-ab`` runs the back-to-back
scan-vs-vector A/B at N >= 256 (medians, per the 2-CPU jitter protocol)
and records it to ``benchmarks/results/scan_engine_speedup.json``.
"""

from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import csv_row, get_env, save_stamped

SIZES = (8, 64, 256, 1024)
QUANTA = {8: 40, 64: 30, 256: 20, 1024: 8}
AB_ROUNDS = 5


def _policies(models):
    from repro.core import isc
    from repro.core.baselines import LinuxScheduler, RandomStaticScheduler
    from repro.core.synpa import SynpaScheduler

    return {
        "linux": lambda: LinuxScheduler(),
        "random": lambda: RandomStaticScheduler(),
        "synpa4": lambda: SynpaScheduler(
            isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"]
        ),
    }


def _scan_policies(models):
    from repro.core import isc
    from repro.smt.scan_engine import ScanPolicy

    return {
        "linux": ScanPolicy(kind="linux"),
        "random": ScanPolicy(kind="static"),
        "synpa4": ScanPolicy(
            kind="synpa", method=isc.SYNPA4_R_FEBE,
            model=models["SYNPA4_R-FEBE"],
        ),
    }


def _engine_speedup(machine, n: int = 256, quanta: int = 30) -> float:
    """Wall-clock ratio loop/vector for one fixed workload (bit-identical)."""
    from repro.core.baselines import RandomStaticScheduler
    from repro.smt import workloads

    profs = workloads.scaled_workload(n, seed=n)
    t0 = time.perf_counter()
    machine.run_workload(profs, RandomStaticScheduler(), seed=1,
                         max_quanta=quanta, engine="loop")
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    machine.run_workload(profs, RandomStaticScheduler(), seed=1,
                         max_quanta=quanta, engine="vector")
    t_vec = time.perf_counter() - t0
    return t_loop / max(t_vec, 1e-9)


def record_scan_ab(machine, models, sizes=(256,), quanta: int = 20,
                   rounds: int = AB_ROUNDS) -> Dict:
    """Back-to-back scan-vs-vector A/B at cluster sizes; medians recorded.

    Per size: the vector arm (``StreamingScheduler`` through ``run_quanta``
    — fused dispatch + host matcher) runs ``rounds`` times and reports the
    median of (policy median + machine mean) per quantum; the scan arm
    compiles once and medians ``rounds`` back-to-back dispatches of the
    whole race.  Written to ``benchmarks/results/scan_engine_speedup.json``
    together with both arms' ground-truth quality.
    """
    import numpy as np

    from repro.core import isc
    from repro.online import StreamingScheduler
    from repro.smt import workloads
    from repro.smt.machine import PhaseTables
    from repro.smt.scan_engine import ScanPolicy

    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    out: Dict[str, Dict] = {
        "protocol": f"back-to-back medians, {rounds} rounds per arm",
    }
    for n in sizes:
        profs = workloads.scaled_workload(n, seed=n)
        tables = PhaseTables.build(profs)
        vec_times = []
        rv = None
        for _ in range(rounds):
            rv = machine.run_quanta(
                profs, StreamingScheduler(method, model),
                n_quanta=quanta, seed=3, tables=tables,
            )
            vec_times.append(
                rv.sched_s_per_quantum_median + rv.machine_s_per_quantum
            )
        rs = machine.run_quanta_multi(
            profs,
            {"synpa4": ScanPolicy(kind="synpa", method=method, model=model)},
            n_quanta=quanta, seed=3, engine="scan", repeats=rounds,
        )["synpa4"]
        vec_ms = float(np.median(vec_times)) * 1e3
        scan_ms = rs.machine_s_per_quantum * 1e3
        out[str(n)] = {
            "quanta": quanta,
            "vector_ms_per_quantum_median": vec_ms,
            "scan_ms_per_quantum_median": scan_ms,
            "speedup": vec_ms / max(scan_ms, 1e-9),
            "vector_mean_true_slowdown": rv.mean_true_slowdown,
            "scan_mean_true_slowdown": rs.mean_true_slowdown,
        }
    save_stamped("scan_engine_speedup.json", out, engine="scan")
    return out


def main(quick: bool = False, smoke: bool = False, engine: str = "vector",
         scan_ab: bool = False) -> str:
    from repro.smt import workloads

    machine, models, _wls = get_env(fast=smoke)
    if smoke:
        sizes = [8, 32]
    else:
        sizes = [n for n in SIZES if n <= (256 if quick else 1024)]
    results: Dict[str, Dict] = {}
    t_total = time.perf_counter()
    for n in sizes:
        profs = workloads.scaled_workload(n, seed=n)
        quanta = QUANTA.get(n, 8)
        if quick or smoke:
            quanta = max(quanta // 2, 4)
        # One PhaseTables build, K policies, bit-identical machine stream.
        if engine == "scan":
            multi = machine.run_quanta_multi(
                profs, _scan_policies(models), n_quanta=quanta, seed=3,
                engine="scan", repeats=3,
            )
            results[str(n)] = {
                pname: {
                    "mean_true_slowdown": res.mean_true_slowdown,
                    "ipc_geomean": res.ipc_geomean,
                }
                for pname, res in multi.items()
            }
            # One dispatch runs all K policies: the wall time is a race
            # total, not attributable per policy (use record_scan_ab's
            # K=1 races for engine-vs-engine per-policy comparisons).
            results[str(n)]["race_total_ms_per_quantum"] = (
                next(iter(multi.values())).machine_s_per_quantum * 1e3
            )
            continue
        multi = machine.run_quanta_multi(
            profs, _policies(models), n_quanta=quanta, seed=3
        )
        results[str(n)] = {
            pname: {
                "mean_true_slowdown": res.mean_true_slowdown,
                "ipc_geomean": res.ipc_geomean,
                "sched_ms_per_quantum": res.sched_s_per_quantum * 1e3,
                "sched_ms_median": res.sched_s_per_quantum_median * 1e3,
                "machine_ms_per_quantum": res.machine_s_per_quantum * 1e3,
            }
            for pname, res in multi.items()
        }
    if not smoke and engine == "vector":
        speedup = _engine_speedup(machine, n=256, quanta=30)
        results["engine_speedup_n256"] = speedup
        save_stamped("cluster_scale.json", results, engine="vector")
    elif not smoke:
        save_stamped("cluster_scale_scan.json", results, engine="scan")
        speedup = float("nan")
    else:
        speedup = float("nan")
    if scan_ab and smoke:
        print("# --record-scan-ab ignored under --smoke: the recorded "
              "A/B is a full-size fitted-model measurement")
        scan_ab = False
    if scan_ab:
        ab = record_scan_ab(machine, models,
                            sizes=tuple(n for n in sizes if n >= 256)
                            or (max(sizes),))
        key = str(max(int(k) for k in ab if k.isdigit()))
        print(f"# scan A/B N={key}: {ab[key]['speedup']:.2f}x "
              f"({ab[key]['vector_ms_per_quantum_median']:.1f} -> "
              f"{ab[key]['scan_ms_per_quantum_median']:.1f} ms/quantum)")

    # Headline: slowdown win of SYNPA4 over Linux at the largest N raced.
    big = results[str(sizes[-1])]
    gain = big["linux"]["mean_true_slowdown"] / big["synpa4"]["mean_true_slowdown"]
    us = (time.perf_counter() - t_total) * 1e6
    return csv_row(
        "cluster_scale", us,
        f"N={sizes[-1]} synpa4 slowdown gain {gain:.3f}x vs linux "
        f"({engine} engine); "
        f"vector engine {speedup:.1f}x vs loop at N=256",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="cap at N=256 with halved horizons")
    ap.add_argument("--smoke", action="store_true",
                    help="sub-minute sanity run (small N, fast models, "
                    "no JSON/engine-speedup refresh)")
    ap.add_argument("--engine", choices=("vector", "scan"),
                    default="vector",
                    help="machine engine: host loop + fused dispatch "
                    "(vector) or the single-dispatch lax.scan race (scan)")
    ap.add_argument("--record-scan-ab", action="store_true",
                    help="record the back-to-back scan-vs-vector A/B "
                    "(medians) to results/scan_engine_speedup.json")
    args = ap.parse_args()
    print(main(quick=args.quick, smoke=args.smoke, engine=args.engine,
               scan_ab=args.record_scan_ab))
