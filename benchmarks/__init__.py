"""Benchmark entry points (one scenario per module; see run.py)."""
