"""Paper Figure 9: SYNPA4_R-FEBE vs Hy-Sched (state-of-the-art heuristic).

Validates §7.3: SYNPA beats Hy-Sched on Mixed workloads by ~3x the gains
(paper: 38% vs 13% over Linux) while the gap narrows on Backend-/Frontend-
intensive workloads (less pairing diversity to exploit).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, get_env
from benchmarks.workload_race import group_mean, race, speedups


def main(quick: bool = False) -> str:
    from repro.core import isc
    from repro.core.baselines import HySchedScheduler, LinuxScheduler
    from repro.core.synpa import SynpaScheduler

    _m, models, _w = get_env()
    t0 = time.time()
    res = race(
        "fig9_race.json",
        {
            "linux": lambda: LinuxScheduler(),
            "hy-sched": lambda: HySchedScheduler(),
            "SYNPA4_R-FEBE": lambda: SynpaScheduler(
                isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"]),
        },
        quick=quick,
    )
    us = (time.time() - t0) * 1e6 / max(len(res), 1)
    tt, ipc = speedups(res)
    syn_fb = group_mean(tt["SYNPA4_R-FEBE"], "fb")
    hy_fb = group_mean(tt["hy-sched"], "fb")
    syn_be = group_mean(tt["SYNPA4_R-FEBE"], "be")
    hy_be = group_mean(tt["hy-sched"], "be")
    syn_fe = group_mean(tt["SYNPA4_R-FEBE"], "fe")
    hy_fe = group_mean(tt["hy-sched"], "fe")
    gain_ratio = (syn_fb - 1) / max(hy_fb - 1, 1e-3)
    derived = (f"mixed_TT: SYNPA {100*(syn_fb-1):.1f}% vs Hy-Sched "
               f"{100*(hy_fb-1):.1f}% (paper 38% vs 13%, ~3x); "
               f"be: {100*(syn_be-1):.1f}%/{100*(hy_be-1):.1f}%; "
               f"fe: {100*(syn_fe-1):.1f}%/{100*(hy_fe-1):.1f}% "
               f"(gap narrows, paper finding); ratio={gain_ratio:.1f}x")
    if not quick:
        assert syn_fb > hy_fb, "SYNPA must beat Hy-Sched on Mixed"
    return csv_row("fig9_vs_hysched", us, derived)


if __name__ == "__main__":
    print(main())
