"""Paper Figure 6: SYNPA3_N vs SYNPA4_N speedups over Linux (TT and IPC).

Validates: SYNPA4 ~38% TT speedup on Mixed workloads; SYNPA4 >= SYNPA3 with
large divergence on high-horizontal-waste workloads; IPC gains small.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from benchmarks.workload_race import group_mean, race, speedups


def main(quick: bool = False) -> str:
    from repro.core import isc
    from repro.core.baselines import LinuxScheduler
    from repro.core.synpa import SynpaScheduler
    from benchmarks.common import get_env

    _m, models, _w = get_env()
    t0 = time.time()
    res = race(
        "fig6_race.json",
        {
            "linux": lambda: LinuxScheduler(),
            "SYNPA3_N": lambda: SynpaScheduler(isc.SYNPA3_N,
                                               models["SYNPA3_N"]),
            "SYNPA4_N": lambda: SynpaScheduler(isc.SYNPA4_N,
                                               models["SYNPA4_N"]),
        },
        quick=quick,
    )
    us = (time.time() - t0) * 1e6 / max(len(res), 1)
    tt, ipc = speedups(res)
    s4_fb = group_mean(tt["SYNPA4_N"], "fb")
    s3_fb = group_mean(tt["SYNPA3_N"], "fb")
    s4_all = float(np.mean(list(tt["SYNPA4_N"].values())))
    ipc4 = float(np.mean(list(ipc["SYNPA4_N"].values())))
    diverging = sorted(
        w for w in tt["SYNPA4_N"]
        if tt["SYNPA4_N"][w] - tt["SYNPA3_N"][w] > 0.10)
    derived = (f"mixed_TT: SYNPA4 {100*(s4_fb-1):.1f}% (paper ~38%), "
               f"SYNPA3 {100*(s3_fb-1):.1f}%; all_TT SYNPA4 "
               f"{100*(s4_all-1):.1f}%; IPC x{ipc4:.3f}; "
               f"SYNPA4>>SYNPA3 on {diverging[:6]}")
    if not quick:
        assert s4_fb > s3_fb - 0.02 and s4_fb > 1.15
    return csv_row("fig6_synpa3_vs_4", us, derived)


if __name__ == "__main__":
    print(main())
