"""Beyond-paper benchmark: SYNPA co-location of TPU jobs (dry-run cells).

Takes the real dry-run roofline records as the job population, pairs jobs
onto shared slices with the SYNPA pipeline, and compares the ground-truth
mean slowdown against random placement and the best/worst placements.
"""

from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, csv_row, get_env, save_json


def _load_records(max_jobs: int = 8):
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun",
                                          "*16x16__full.json")))
    records = []
    for p in paths:
        with open(p) as f:
            r = json.load(f)
        if r.get("mesh") == "16x16":
            records.append(r)
    if len(records) < max_jobs:
        return None
    # diverse selection: order by dominant term then roofline fraction
    records.sort(key=lambda r: (r["dominant"], -r["collective_s"]))
    step = max(len(records) // max_jobs, 1)
    sel = records[::step][:max_jobs]
    return sel if len(sel) == max_jobs else records[:max_jobs]


def main(quick: bool = False) -> str:
    from repro.core import matching
    from repro.core.colocation import (
        evaluate_placement,
        job_stack_from_record,
        plan_colocation,
    )

    _m, models, _w = get_env()
    records = _load_records()
    if records is None:
        return csv_row("colocation_synpa", 0.0,
                       "SKIPPED (dry-run records not yet available)")
    t0 = time.time()
    plan = plan_colocation(records, models["SYNPA4_R-FEBE"])
    us = (time.time() - t0) * 1e6

    synpa_cost = evaluate_placement(records, plan.pairs)
    rng = np.random.default_rng(0)
    rnd = []
    n = len(records)
    for _ in range(200):
        perm = rng.permutation(n)
        pairs = [(int(perm[2 * k]), int(perm[2 * k + 1]))
                 for k in range(n // 2)]
        rnd.append(evaluate_placement(records, pairs))
    # oracle best via exact matching on the ground-truth costs
    from repro.core.colocation import job_profile
    from repro.smt.machine import MachineParams, true_slowdown

    profiles = [job_profile(f"{r['arch']}/{r['shape']}",
                            job_stack_from_record(r)) for r in records]
    params = MachineParams()
    gt = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j:
                gt[i, j] = true_slowdown(profiles[i].phase(0), profiles[i],
                                         profiles[j].phase(0), params)
    sym = gt + gt.T
    np.fill_diagonal(sym, 1e9)
    best = matching.min_cost_pairs(sym)
    best_cost = evaluate_placement(records, best)

    save_json("colocation.json", {
        "jobs": plan.job_names,
        "synpa_pairs": plan.named_pairs(),
        "synpa_mean_slowdown": synpa_cost,
        "random_mean_slowdown": float(np.mean(rnd)),
        "oracle_mean_slowdown": best_cost,
    })
    gain = float(np.mean(rnd)) / synpa_cost
    derived = (f"mean_slowdown: synpa={synpa_cost:.3f} "
               f"random={np.mean(rnd):.3f} oracle={best_cost:.3f}; "
               f"synpa_vs_random={100*(gain-1):.1f}% better")
    return csv_row("colocation_synpa", us, derived)


if __name__ == "__main__":
    print(main())
