"""Render the roofline table + perf log into EXPERIMENTS.md.

Run whenever new dry-run/hillclimb records land:
    PYTHONPATH=src python tools/finalize_experiments.py
"""

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "benchmarks", "results")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def _bottleneck_note(r):
    d = r["dominant"]
    arch, shape = r["arch"], r["shape"]
    if d == "collective":
        return ("reduce per-layer weight gathers (drop FSDP on the hot "
                "params / shard over pod too) or overlap via scan")
    if d == "memory":
        if "decode" in shape or "long" in shape:
            return ("shard the KV-length dim over the model axis; "
                    "fuse decode attention (Pallas decode_attention)")
        return ("Pallas flash attention removes the S^2 score traffic; "
                "remat policy trades the rest")
    return "raise arithmetic intensity (larger per-chip tiles, less remat)"


def roofline_md():
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "dryrun",
                                           "*__16x16__full.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("mesh") == "16x16":
            rows.append(r)
    if not rows:
        return "*(sweep still running — no single-pod records yet)*"
    mp = len(glob.glob(os.path.join(RESULTS, "dryrun",
                                    "*__2x16x16__*.json")))
    lines = [
        f"**{len(rows)} single-pod cells baselined; {mp} multi-pod cells "
        f"compiled (pod-axis coherence proven).**", "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful | roofline | GiB/dev | fits | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        gib = (r.get("bytes_per_device") or 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.1f}% | {gib:.1f} | "
            f"{'y' if r.get('fits_hbm') else 'n'} | "
            f"{_bottleneck_note(r)} |")
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines += ["", f"Dominant-term census: {doms}."]
    return "\n".join(lines)


def perf_md():
    path = os.path.join(RESULTS, "perf_log.json")
    if not os.path.exists(path):
        return "*(hillclimb log not yet produced)*"
    with open(path) as f:
        log = json.load(f)
    by_cell = {}
    for e in log:
        cell, step = e["key"].split("/", 1)
        by_cell.setdefault(cell, []).append((step, e))
    out = []
    # headline: paper-faithful baseline vs best optimized variant per cell
    out.append("**Headline (baseline -> best measured variant, same "
               "HLO-derived yardstick):**\n")
    out.append("| cell | baseline bound_s | best bound_s | Δ | baseline "
               "roofline | best roofline |")
    out.append("|---|---|---|---|---|---|")
    for cell, steps in by_cell.items():
        recs = [e["record"] for _s, e in steps if "record" in e]
        full = [r for r in recs
                if not r.get("overrides", {}).get("scan_layers", False)]
        if not full:
            continue
        base = next((e["record"] for s, e in steps
                     if s == "baseline" and "record" in e), full[0])
        best = min(full, key=lambda r: r["bound_s"])
        out.append(
            f"| {cell} | {base['bound_s']:.3f} | {best['bound_s']:.3f} | "
            f"{100*(best['bound_s']/base['bound_s']-1):+.1f}% | "
            f"{100*base['roofline_fraction']:.1f}% | "
            f"{100*best['roofline_fraction']:.1f}% |")
    out.append("")
    out.append("(scan-only probes measure state-memory effects and are "
               "excluded from bound comparisons; decode cells' roofline "
               "fraction is compute-referenced and intrinsically ~0 — the "
               "memory term *is* their score.)\n")
    for cell, steps in by_cell.items():
        out.append(f"### {cell}")
        base = None
        for step, e in steps:
            if "error" in e:
                out.append(f"* **{step}** — {e['hypothesis']}\n"
                           f"  - FAILED: `{e['error']}`")
                continue
            r = e["record"]
            terms = (f"compute {r['compute_s']:.3f}s / memory "
                     f"{r['memory_s']:.3f}s / collective "
                     f"{r['collective_s']:.3f}s; dominant {r['dominant']}; "
                     f"useful {r['useful_flops_ratio']:.2f}; "
                     f"roofline {100*r['roofline_fraction']:.1f}%; "
                     f"{(r.get('bytes_per_device') or 0)/2**30:.1f} GiB/dev")
            if step == "baseline":
                base = r
                out.append(f"* **baseline** — {e['hypothesis']}\n  - {terms}")
                continue
            verdict = ""
            if base is not None:
                db = r["bound_s"] / max(base["bound_s"], 1e-12) - 1
                dd = (r[f"{base['dominant']}_s"]
                      / max(base[f"{base['dominant']}_s"], 1e-12) - 1)
                verdict = (f"\n  - vs baseline: bound {100*db:+.1f}%, "
                           f"baseline-dominant term {100*dd:+.1f}% "
                           f"({'confirmed' if dd < -0.03 or db < -0.03 else 'refuted/neutral'})")
            out.append(f"* **{step}** — {e['hypothesis']}\n  - {terms}"
                       + verdict)
        out.append("")
    return "\n".join(out)


def main():
    with open(EXP) as f:
        text = f.read()
    text = text.split("<!-- ROOFLINE_TABLE -->")[0] + "<!-- ROOFLINE_TABLE -->\n\n"
    text += roofline_md() + "\n\n"
    # keep everything between the markers regenerated
    text += """---

## §Perf — hillclimbing (deliverable, 3 cells)

Per the brief: every cell is baselined (table above); three cells are
hillclimbed with explicit hypothesis -> change -> measure -> confirm/refute
cycles (`tools/hillclimb.py`, log: `benchmarks/results/perf_log.json`):

1. **kimi-k2-1t-a32b x train_4k** — most collective-bound (the paper-table
   arch; per-layer FSDP expert gathers dominate).
2. **llama3.2-3b x decode_32k** — memory-bound serving cell.
3. **gemma-7b x train_4k** — the dense-train representative (attention S^2
   memory, remat-recompute trade).

<!-- PERF_LOG -->

"""
    text += perf_md() + "\n"
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
