#!/usr/bin/env bash
# Tier-1 verification — the exact command ROADMAP.md names, runnable
# identically on a laptop and in CI.  Any extra args are passed to pytest,
# e.g.  tools/run_tier1.sh -m "not slow"  for a quick pre-push loop.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
