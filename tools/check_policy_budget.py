"""Policy-time regression guard: warm-streaming + scan SYNPA4 at N=256.

Measures the steady-state (median) policy wall-time per quantum of the
default ``StreamingScheduler`` on a closed N=256 population — the fused
per-quantum dispatch plus the incremental matcher — the per-quantum
wall time of the single-dispatch scan engine
(``repro.smt.scan_engine.run_quanta_scan``, machine+policy indivisible),
*and* the per-quantum wall time of the device-resident open system
(``ClusterSim(engine="scan")`` on a rho=1.0 churn cell, one dispatch per
run), and fails (exit 1) if any regresses more than ``MAX_REGRESSION``x
over the recorded baseline in
``benchmarks/results/policy_time_n256.json``.  The baseline carries the
RNG stream version stamps (``benchmarks.common.version_stamp``); a
baseline recorded under different stream layouts is refused and must be
re-recorded.

Run via ``tools/run_bench_smoke.sh`` (and the slow-marked
``tests/test_bench_smoke.py``), so a change that quietly de-fuses the hot
path — or breaks the scan loop back into per-quantum dispatches — cannot
land without tier-1 noticing.  ``--record`` refreshes the baseline
instead of checking against it (use after an intentional change, on an
otherwise quiet machine).

The measurement uses the fast-campaign models (the smoke tier's cache):
model coefficients only steer *which* local minimum the solver walks to,
not how much work a quantum costs, and the fast cache keeps the guard
inside the smoke-tier time budget.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

BASELINE = os.path.join(_ROOT, "benchmarks", "results",
                        "policy_time_n256.json")
N_APPS = 256
N_QUANTA = 12          # median over the horizon absorbs the compile quantum
SCAN_REPEATS = 3       # scan: median over re-dispatches (compile excluded)
MAX_REGRESSION = 2.0


def measure() -> dict:
    """Best-of-two measurement of both engines' steady per-quantum cost.

    The dev container's wall-clock jitter under load spikes exceeds the
    2x regression budget; taking the minimum over two back-to-back runs
    de-flakes the guard (a load spike inflates a run, a real regression
    inflates both) while the defects this guard exists for — a de-fused
    hot path, a scan loop broken back into per-quantum dispatches — are
    order-of-magnitude, not 2x.
    """
    from benchmarks.common import get_env, version_stamp
    from benchmarks.online_churn import TARGET_SCALE, mean_service_quanta
    from repro.core import isc
    from repro.online import ClusterSim, PoissonArrivals, StreamingScheduler
    from repro.smt import workloads
    from repro.smt.apps import pool_profiles
    from repro.smt.scan_engine import ScanPolicy

    machine, models, _ = get_env(fast=True)
    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    profs = workloads.scaled_workload(N_APPS, seed=N_APPS)
    pool = pool_profiles()
    device_spec = ScanPolicy(kind="synpa", method=method, model=model)
    # The device-sim steady-state cell: rho=1.0 traffic at N=256 capacity
    # under the benchmark grid's own mean-service mapping, so the guard
    # always measures the published cell.  One sim (and one PhaseTables
    # build) serves both guard iterations; the compiled race is cached.
    rate = N_APPS / mean_service_quanta(machine)
    dev_sim = ClusterSim(
        machine, pool, N_APPS // 2, device_spec,
        PoissonArrivals(rate=rate, n_pool=len(pool)),
        seed=11, target_scale=TARGET_SCALE, engine="scan",
    )
    stream_us, stream_mean_us = np.inf, np.inf
    scan_us, device_us = np.inf, np.inf
    for _ in range(2):
        res = machine.run_quanta_multi(
            profs,
            {"synpa4-stream": lambda: StreamingScheduler(method, model)},
            n_quanta=N_QUANTA,
            seed=3,
        )["synpa4-stream"]
        scan = machine.run_quanta_multi(
            profs,
            {"synpa4-scan": ScanPolicy(kind="synpa", method=method,
                                       model=model)},
            n_quanta=N_QUANTA, seed=3, engine="scan", repeats=SCAN_REPEATS,
        )["synpa4-scan"]
        dev = dev_sim.run(N_QUANTA, repeats=SCAN_REPEATS)
        stream_us = min(stream_us, res.sched_s_per_quantum_median * 1e6)
        stream_mean_us = min(stream_mean_us, res.sched_s_per_quantum * 1e6)
        scan_us = min(scan_us, scan.machine_s_per_quantum * 1e6)
        device_us = min(device_us, float(np.median(dev.policy_s)) * 1e6)
    return {
        "n": N_APPS,
        "quanta": N_QUANTA,
        "stream_median_us": stream_us,
        "stream_mean_us": stream_mean_us,
        "scan_total_median_us": scan_us,
        "device_sim_median_us": device_us,
        "recorded_unix": time.time(),
        **version_stamp(engine="scan"),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", action="store_true",
                    help="write the measurement as the new baseline")
    args = ap.parse_args()

    got = measure()
    if args.record:
        with open(BASELINE, "w") as f:
            json.dump(got, f, indent=2)
        print(f"policy_guard: recorded baseline "
              f"{got['stream_median_us']:.0f} us/quantum (median, N={N_APPS})"
              f", scan {got['scan_total_median_us']:.0f} us/quantum, "
              f"device sim {got['device_sim_median_us']:.0f} us/quantum")
        return 0

    if not os.path.exists(BASELINE):
        print(f"policy_guard: no baseline at {BASELINE}; "
              "run with --record first", file=sys.stderr)
        return 1
    from benchmarks.common import load_stamped

    base = load_stamped(os.path.basename(BASELINE))
    if base is None:
        print("policy_guard: baseline stamped with stale RNG stream "
              "versions; run --record on the current code first",
              file=sys.stderr)
        return 1
    budget = base["stream_median_us"] * MAX_REGRESSION
    ok = got["stream_median_us"] <= budget
    print(
        f"policy_guard: warm-streaming N={N_APPS} median "
        f"{got['stream_median_us']:.0f} us/quantum vs baseline "
        f"{base['stream_median_us']:.0f} (budget {budget:.0f}) -> "
        f"{'OK' if ok else 'REGRESSION'}"
    )
    def _guard(key: str, label: str) -> bool:
        if key not in base:
            print(f"policy_guard: baseline has no {label} entry; run "
                  "--record to start guarding it")
            return True
        b = base[key] * MAX_REGRESSION
        good = got[key] <= b
        print(
            f"policy_guard: {label} N={N_APPS} median "
            f"{got[key]:.0f} us/quantum vs baseline {base[key]:.0f} "
            f"(budget {b:.0f}) -> {'OK' if good else 'REGRESSION'}"
        )
        return good

    scan_ok = _guard("scan_total_median_us", "scan-engine")
    device_ok = _guard("device_sim_median_us", "device-sim")
    return 0 if (ok and scan_ok and device_ok) else 1


if __name__ == "__main__":
    sys.exit(main())
