"""Policy-time regression guard: warm-streaming SYNPA4 at N=256.

Measures the steady-state (median) policy wall-time per quantum of the
default ``StreamingScheduler`` on a closed N=256 population — the fused
per-quantum dispatch plus the incremental matcher — and fails (exit 1)
if it regresses more than ``MAX_REGRESSION``x over the recorded baseline
in ``benchmarks/results/policy_time_n256.json``.

Run via ``tools/run_bench_smoke.sh`` (and the slow-marked
``tests/test_bench_smoke.py``), so a change that quietly de-fuses the hot
path cannot land without tier-1 noticing.  ``--record`` refreshes the
baseline instead of checking against it (use after an intentional change,
on an otherwise quiet machine).

The measurement uses the fast-campaign models (the smoke tier's cache):
model coefficients only steer *which* local minimum the solver walks to,
not how much work a quantum costs, and the fast cache keeps the guard
inside the smoke-tier time budget.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

BASELINE = os.path.join(_ROOT, "benchmarks", "results",
                        "policy_time_n256.json")
N_APPS = 256
N_QUANTA = 12          # median over the horizon absorbs the compile quantum
MAX_REGRESSION = 2.0


def measure() -> dict:
    from benchmarks.common import get_env
    from repro.core import isc
    from repro.online import StreamingScheduler
    from repro.smt import workloads

    machine, models, _ = get_env(fast=True)
    profs = workloads.scaled_workload(N_APPS, seed=N_APPS)
    res = machine.run_quanta_multi(
        profs,
        {"synpa4-stream": lambda: StreamingScheduler(
            isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"])},
        n_quanta=N_QUANTA,
        seed=3,
    )["synpa4-stream"]
    return {
        "n": N_APPS,
        "quanta": N_QUANTA,
        "stream_median_us": res.sched_s_per_quantum_median * 1e6,
        "stream_mean_us": res.sched_s_per_quantum * 1e6,
        "recorded_unix": time.time(),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", action="store_true",
                    help="write the measurement as the new baseline")
    args = ap.parse_args()

    got = measure()
    if args.record:
        with open(BASELINE, "w") as f:
            json.dump(got, f, indent=2)
        print(f"policy_guard: recorded baseline "
              f"{got['stream_median_us']:.0f} us/quantum (median, N={N_APPS})")
        return 0

    if not os.path.exists(BASELINE):
        print(f"policy_guard: no baseline at {BASELINE}; "
              "run with --record first", file=sys.stderr)
        return 1
    with open(BASELINE) as f:
        base = json.load(f)
    budget = base["stream_median_us"] * MAX_REGRESSION
    ok = got["stream_median_us"] <= budget
    print(
        f"policy_guard: warm-streaming N={N_APPS} median "
        f"{got['stream_median_us']:.0f} us/quantum vs baseline "
        f"{base['stream_median_us']:.0f} (budget {budget:.0f}) -> "
        f"{'OK' if ok else 'REGRESSION'}"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
