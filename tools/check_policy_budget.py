"""Policy-time regression guard: warm-streaming + scan SYNPA4 at N=256.

Measures the steady-state (median) policy wall-time per quantum of the
default ``StreamingScheduler`` on a closed N=256 population — the fused
per-quantum dispatch plus the incremental matcher — the per-quantum
wall time of the single-dispatch scan engine
(``repro.smt.scan_engine.run_quanta_scan``, machine+policy indivisible),
the per-quantum wall time of the device-resident open system
(``ClusterSim(engine="scan")`` on a rho=1.0 churn cell, one dispatch per
run — **faults off**, so this number is the steady-state guard the
fault-injection PR holds itself to), the same cell with a light
``FaultProfile`` injected (the fault path compiles extra mask work into
the race; this arm keeps its cost honest), *and* the telemetry-ring
overhead of the scan engine
(``telemetry=True`` vs off on the same race) — and fails (exit 1) if any
timing regresses more than ``MAX_REGRESSION``x over the recorded
baseline in ``benchmarks/results/policy_time_n256.json``.

The baseline is a stamped :mod:`repro.obs.metrics` run export — the
``metrics`` block holds the comparable numbers and the RNG stream
stamps ride at the top level; a baseline recorded under different
stream layouts (or schema) is refused and must be re-recorded.  Each
timing is recorded with a seeded bootstrap interval over its
back-to-back passes (``<key>_ci_lo``/``<key>_ci_hi``); the guard
compares the live value against the *CI upper edge* times
``MAX_REGRESSION`` — noise widens the interval instead of faking a
tight baseline — falling back to the point estimate for pre-interval
baselines.  The
recorded ``telemetry_overhead_x`` must come in at or under
``TELEMETRY_BUDGET_X`` (the ISSUE's 1.10x contract) — ``--record``
retries the measurement and refuses to write a baseline that breaches
it, and ``tests/test_obs.py`` asserts the recorded value stays inside
the budget.

Beyond timing, the guard also measures prediction *accuracy*: a small
open churn cell per seed in ``ACC_SEEDS`` runs with the per-app rings
on (``app_telemetry=True``) and the cross-seed overall Eq.4 MAPE is
guarded against the recorded baseline with the tight
``ACC_REGRESSION`` budget — accuracy carries no wall-clock jitter, so a
breach means the policy's predictions actually got worse, not that the
box was busy.  The whole measurement runs under ``repro.obs.trace`` so
the baseline records its compile/steady split
(``compile_total_ms``/``compile_spans`` next to the steady medians).

Run via ``tools/run_bench_smoke.sh`` (and the slow-marked
``tests/test_bench_smoke.py``), so a change that quietly de-fuses the hot
path — or breaks the scan loop back into per-quantum dispatches, or
makes the telemetry ring expensive, or silently degrades the pair
predictor — cannot land without tier-1 noticing.  ``--record``
refreshes the baseline instead of checking against it (use after an
intentional change, on an otherwise quiet machine) and appends the
recorded export as one line to the append-only
``benchmarks/results/history/policy_time_n256.jsonl`` ledger, trended
by ``tools/perf_history.py``.

The measurement uses the fast-campaign models (the smoke tier's cache):
model coefficients only steer *which* local minimum the solver walks to,
not how much work a quantum costs, and the fast cache keeps the guard
inside the smoke-tier time budget.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

BASELINE = os.path.join(_ROOT, "benchmarks", "results",
                        "policy_time_n256.json")
#: Append-only ledger of recorded baselines (one JSON line per
#: ``--record``), trended by ``tools/perf_history.py``.
HISTORY = os.path.join(_ROOT, "benchmarks", "results", "history",
                       "policy_time_n256.jsonl")
N_APPS = 256
N_QUANTA = 12          # median over the horizon absorbs the compile quantum
SCAN_REPEATS = 3       # scan: median over re-dispatches (compile excluded)
MAX_REGRESSION = 2.0
#: Recorded telemetry-on / telemetry-off dispatch-time ratio budget.
TELEMETRY_BUDGET_X = 1.10
#: Prediction-accuracy guard cell: a small open-system churn cell per
#: seed, rings on, overall Eq.4 MAPE aggregated across seeds.
ACC_SEEDS = (13, 17, 19)
ACC_QUANTA = 40
ACC_CORES = 8
ACC_RATE = 1.5
#: Allowed live-MAPE growth over the recorded baseline's CI upper edge.
#: Accuracy is deterministic given the stamps (no wall-clock jitter), so
#: the budget is much tighter than the 2x timing headroom — it exists to
#: absorb genuine model-cache refreshes, not measurement noise.
ACC_REGRESSION = 1.25


def measure(record: bool = False) -> dict:
    """Best-of-two measurement of the engines' steady per-quantum cost.

    The dev container's wall-clock jitter under load spikes exceeds the
    2x regression budget; taking the minimum over two back-to-back runs
    de-flakes the guard (a load spike inflates a run, a real regression
    inflates both) while the defects this guard exists for — a de-fused
    hot path, a scan loop broken back into per-quantum dispatches — are
    order-of-magnitude, not 2x.  ``record=True`` adds up to two extra
    passes over the telemetry pair when jitter pushes the overhead ratio
    past its budget, so a recorded baseline never starts life in breach.
    """
    from benchmarks.common import get_env
    from benchmarks.online_churn import TARGET_SCALE, mean_service_quanta
    from repro.core import isc
    from repro.obs import accuracy as obs_accuracy
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.online import (
        ClusterSim,
        FaultProfile,
        PoissonArrivals,
        StreamingScheduler,
    )
    from repro.smt import workloads
    from repro.smt.apps import pool_profiles
    from repro.smt.scan_engine import ScanPolicy

    machine, models, _ = get_env(fast=True)
    method = isc.SYNPA4_R_FEBE
    model = models["SYNPA4_R-FEBE"]
    profs = workloads.scaled_workload(N_APPS, seed=N_APPS)
    pool = pool_profiles()
    device_spec = ScanPolicy(kind="synpa", method=method, model=model)
    # The device-sim steady-state cell: rho=1.0 traffic at N=256 capacity
    # under the benchmark grid's own mean-service mapping, so the guard
    # always measures the published cell.  One sim (and one PhaseTables
    # build) serves both guard iterations; the compiled race is cached.
    rate = N_APPS / mean_service_quanta(machine)
    dev_sim = ClusterSim(
        machine, pool, N_APPS // 2, device_spec,
        PoissonArrivals(rate=rate, n_pool=len(pool)),
        seed=11, target_scale=TARGET_SCALE, engine="scan",
    )
    # Same cell with a light fault profile (MTTF/MTTR draws + one
    # straggler window): guards the compiled-in fault path's cost.  The
    # faults-off ``dev_sim`` above stays the steady-state guard.
    fault_sim = ClusterSim(
        machine, pool, N_APPS // 2, device_spec,
        PoissonArrivals(rate=rate, n_pool=len(pool)),
        seed=11, target_scale=TARGET_SCALE, engine="scan",
        faults=FaultProfile(
            mttf_quanta=4.0 * N_QUANTA, mttr_quanta=N_QUANTA / 2,
            straggle=((0, 2, N_QUANTA, 0.5),),
        ),
    )

    def scan_race(telemetry: bool) -> float:
        res = machine.run_quanta_multi(
            profs,
            {"synpa4-scan": ScanPolicy(kind="synpa", method=method,
                                       model=model)},
            n_quanta=N_QUANTA, seed=3, engine="scan", repeats=SCAN_REPEATS,
            telemetry=telemetry,
        )["synpa4-scan"]
        return res.machine_s_per_quantum * 1e6

    # Trace the whole measurement: the span table gives the recorded
    # compile/steady split (compile cost is real user-visible latency
    # but must never leak into the steady medians the guard compares),
    # and enabling tracing arms the dispatch-cost / jax.monitoring
    # instants for free.
    trace_was_on = obs_trace.enabled()
    obs_trace.enable(clear=not trace_was_on)

    samples: dict = {
        "stream_median_us": [],
        "stream_mean_us": [],
        "device_sim_median_us": [],
        "scan_total_median_us": [],
        "scan_telemetry_median_us": [],
        "device_sim_faulted_median_us": [],
    }
    for _ in range(2):
        res = machine.run_quanta_multi(
            profs,
            {"synpa4-stream": lambda: StreamingScheduler(method, model)},
            n_quanta=N_QUANTA,
            seed=3,
        )["synpa4-stream"]
        dev = dev_sim.run(N_QUANTA, repeats=SCAN_REPEATS)
        samples["stream_median_us"].append(
            res.sched_s_per_quantum_median * 1e6)
        samples["stream_mean_us"].append(res.sched_s_per_quantum * 1e6)
        samples["device_sim_median_us"].append(
            float(np.median(dev.policy_s)) * 1e6)
    # The scan arms re-jit per call (no race cache in the closed engine),
    # so each runs once — the median over SCAN_REPEATS re-dispatches
    # inside the call is the de-flake; only ``--record`` pays for extra
    # passes, and only when jitter pushed the ratio past its budget.
    samples["scan_total_median_us"].append(scan_race(telemetry=False))
    samples["scan_telemetry_median_us"].append(scan_race(telemetry=True))
    faulted = fault_sim.run(N_QUANTA, repeats=SCAN_REPEATS)
    samples["device_sim_faulted_median_us"].append(
        float(np.median(faulted.policy_s)) * 1e6)
    if record:
        for _ in range(2):
            if (min(samples["scan_telemetry_median_us"])
                    / min(samples["scan_total_median_us"])
                    <= TELEMETRY_BUDGET_X):
                break
            samples["scan_total_median_us"].append(
                scan_race(telemetry=False))
            samples["scan_telemetry_median_us"].append(
                scan_race(telemetry=True))
    # Prediction-accuracy arm: a small open churn cell per seed with the
    # per-app rings on; the guard metric is the cross-seed mean of each
    # run's overall Eq.4 MAPE (deterministic given the stamps — the CI
    # covers seed-to-seed workload spread, not clock noise).
    acc_mapes, acc_worsts = [], []
    for s in ACC_SEEDS:
        cell = ClusterSim(
            machine, pool, ACC_CORES, device_spec,
            PoissonArrivals(rate=ACC_RATE, n_pool=len(pool)),
            seed=s, target_scale=TARGET_SCALE, engine="scan",
        )
        st = cell.run(ACC_QUANTA, app_telemetry=True)
        rep = obs_accuracy.accuracy_report(st.app_telemetry)
        acc_mapes.append(rep["overall"]["mape"])
        acc_worsts.append(max(
            (v["mape"] for v in rep["per_app"].values()), default=0.0))

    # Point estimate stays best-of-passes (a load spike inflates one
    # pass, a real regression inflates all); the bootstrap interval over
    # the passes is what the guard compares against — a noisy baseline
    # carries a wide CI instead of a falsely tight point.
    from repro.smt.metrics import bootstrap_ci

    metrics = {}
    for key, vals in samples.items():
        point = float(min(vals))
        _, lo, hi = bootstrap_ci(vals, stat=np.min)
        metrics[key] = point
        metrics[key + "_ci_lo"] = lo
        metrics[key + "_ci_hi"] = hi
    metrics["telemetry_overhead_x"] = (
        metrics["scan_telemetry_median_us"]
        / metrics["scan_total_median_us"]
    )
    point = float(np.mean(acc_mapes))
    _, lo, hi = bootstrap_ci(acc_mapes, stat=np.mean)
    metrics["acc_open_mape"] = point
    metrics["acc_open_mape_ci_lo"] = lo
    metrics["acc_open_mape_ci_hi"] = hi
    metrics["acc_open_mape_worst_app"] = float(np.mean(acc_worsts))
    # The compile/steady split: total wall spent in compile-tagged spans
    # across the measurement (a cold persistent cache pays it, a warm one
    # mostly skips it) next to the steady medians above.
    bd = obs_trace.breakdown()
    compile_rows = {k: v for k, v in bd.items() if "compile" in k}
    metrics["compile_total_ms"] = float(
        sum(v["total_us"] for v in compile_rows.values()) / 1e3)
    metrics["compile_spans"] = float(
        sum(v["count"] for v in compile_rows.values()))
    if not trace_was_on:
        obs_trace.disable()
    return obs_metrics.export_run(
        name="policy_time_n256",
        engine="scan",
        metrics=metrics,
        meta={"n": N_APPS, "quanta": N_QUANTA, "repeats": SCAN_REPEATS,
              "acc_seeds": list(ACC_SEEDS), "acc_quanta": ACC_QUANTA,
              "ci": "seeded percentile bootstrap over back-to-back "
                    "passes, stat=min (timings) / mean (accuracy)"},
        faults=True,
    )


def append_history(run: dict, path: str = HISTORY) -> str:
    """Append one JSON line for a recorded baseline to the perf ledger.

    The ledger is append-only — every ``--record`` adds a line (stamps,
    the full metric block with CI bounds, and the compile/steady split)
    and never rewrites old ones, so ``tools/perf_history.py`` can trend
    steady cost and prediction accuracy across the PR sequence even as
    the baseline file itself is overwritten in place.
    """
    import json

    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(run, sort_keys=True) + "\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--record", action="store_true",
                    help="write the measurement as the new baseline")
    args = ap.parse_args()

    from repro.obs import metrics as obs_metrics

    run = measure(record=args.record)
    got = run["metrics"]
    if args.record:
        if got["telemetry_overhead_x"] > TELEMETRY_BUDGET_X:
            print(
                f"policy_guard: refusing to record a baseline with "
                f"telemetry overhead {got['telemetry_overhead_x']:.3f}x "
                f"> {TELEMETRY_BUDGET_X:.2f}x budget", file=sys.stderr,
            )
            return 1
        obs_metrics.save_run(BASELINE, run)
        append_history(run)
        print(f"policy_guard: recorded baseline "
              f"{got['stream_median_us']:.0f} us/quantum (median, N={N_APPS})"
              f", scan {got['scan_total_median_us']:.0f} us/quantum, "
              f"device sim {got['device_sim_median_us']:.0f} us/quantum, "
              f"telemetry overhead {got['telemetry_overhead_x']:.3f}x, "
              f"open MAPE {got['acc_open_mape']:.2%} "
              f"(compile {got['compile_total_ms']:.0f} ms across "
              f"{got['compile_spans']:.0f} spans); history -> "
              f"{os.path.relpath(HISTORY, _ROOT)}")
        return 0

    # The guard *diffs against* (and --record overwrites) the baseline:
    # write path, so a schema-v1 baseline is refused with a re-record
    # notice instead of being compared across schemas.
    base_run = obs_metrics.load_run(BASELINE, write=True)
    if base_run is None:
        print(f"policy_guard: no usable baseline at {BASELINE} (missing, "
              "stale-stamped or pre-obs format); run with --record first",
              file=sys.stderr)
        return 1
    base = base_run["metrics"]

    def _guard(key: str, label: str) -> bool:
        if key not in base:
            print(f"policy_guard: baseline has no {label} entry; run "
                  "--record to start guarding it")
            return True
        # Compare against the baseline CI's upper edge, not the point
        # estimate: a baseline recorded under jitter carries its noise
        # as interval width instead of tripping the guard later.  Old
        # baselines without interval fields fall back to the point.
        anchor = max(base[key], base.get(key + "_ci_hi", base[key]))
        b = anchor * MAX_REGRESSION
        good = got[key] <= b
        tag = "ci-hi" if key + "_ci_hi" in base else "point"
        print(
            f"policy_guard: {label} N={N_APPS} median "
            f"{got[key]:.0f} us/quantum vs baseline {base[key]:.0f} "
            f"({tag} budget {b:.0f}) -> {'OK' if good else 'REGRESSION'}"
        )
        return good

    ok = _guard("stream_median_us", "warm-streaming")
    scan_ok = _guard("scan_total_median_us", "scan-engine")
    tlm_ok = _guard("scan_telemetry_median_us", "scan-telemetry")
    device_ok = _guard("device_sim_median_us", "device-sim (faults off)")
    faults_ok = _guard("device_sim_faulted_median_us",
                       "device-sim (faults on)")
    # The live overhead ratio gets the same 2x jitter headroom as the
    # absolute timings; the strict 1.10x contract binds the *recorded*
    # value (enforced at --record time and by tests/test_obs.py).
    ratio_budget = TELEMETRY_BUDGET_X * MAX_REGRESSION
    ratio_ok = got["telemetry_overhead_x"] <= ratio_budget
    print(
        f"policy_guard: telemetry overhead "
        f"{got['telemetry_overhead_x']:.3f}x vs recorded "
        f"{base.get('telemetry_overhead_x', float('nan')):.3f}x "
        f"(live budget {ratio_budget:.2f}x) -> "
        f"{'OK' if ratio_ok else 'REGRESSION'}"
    )
    # Prediction-accuracy arm: same CI-anchored machinery as the timing
    # guards, but with the tight ACC_REGRESSION budget — MAPE carries no
    # wall-clock jitter, so growth past the recorded CI edge means the
    # model/policy surface actually got less accurate.
    if "acc_open_mape" not in base:
        print("policy_guard: baseline has no accuracy entry; run "
              "--record to start guarding prediction error")
        acc_ok = True
    else:
        anchor = max(base["acc_open_mape"],
                     base.get("acc_open_mape_ci_hi",
                              base["acc_open_mape"]))
        budget = anchor * ACC_REGRESSION
        acc_ok = got["acc_open_mape"] <= budget
        print(
            f"policy_guard: open-cell MAPE {got['acc_open_mape']:.2%} vs "
            f"baseline {base['acc_open_mape']:.2%} "
            f"(ci-hi budget {budget:.2%}) -> "
            f"{'OK' if acc_ok else 'ACCURACY REGRESSION'}"
        )
    return 0 if (ok and scan_ok and tlm_ok and device_ok and faults_ok
                 and ratio_ok and acc_ok) else 1


if __name__ == "__main__":
    sys.exit(main())
