"""Perf hillclimbing driver (§Perf): hypothesis -> change -> measure -> log.

Each experiment is one run_cell invocation with explicit knobs; every record
(terms + knobs + hypothesis text) is appended to
benchmarks/results/perf_log.json so EXPERIMENTS.md §Perf can cite the whole
path, confirmed and refuted alike.

    PYTHONPATH=src python tools/hillclimb.py --cell kimi_train --step NAME
    PYTHONPATH=src python tools/hillclimb.py --list
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results")
LOG = os.path.join(RESULTS, "perf_log.json")


# Each step: (cell, name, hypothesis, kwargs for run_cell)
EXPERIMENTS = {
    # ---------------- kimi-k2 1T train (most collective-bound) ----------
    "kimi_train": [
        ("baseline", "Paper-faithful baseline: FSDP everywhere, fp32 "
         "moments, full remat, shard_map EP.  Expect collective-dominated "
         "by per-layer expert weight all-gathers (2TB weights / 16-way "
         "model shard re-gathered over the data axis every layer).",
         dict()),
        ("bf16_moments", "Adam moments in bf16 halve optimizer HBM "
         "(10->6 bytes/param); bytes/dev drops ~25%+, collectives "
         "unchanged (measured on the scanned lowering: the effect is "
         "state-memory, not FLOPs).",
         dict(opt_kw={"moment_dtype": "bfloat16"}, scan_only=True)),
        ("no_fsdp_experts", "Keep experts sharded over 'model' only (EP) "
         "without the d_model FSDP shard: kills the per-layer expert "
         "all-gather over the data axis (the dominant collective; "
         "analytically ~2TB*(15/16)*3 passes / 16 links = -28s of the "
         "46.4s baseline collective term) at the cost of 16x more expert "
         "bytes per device (measured here on the scanned lowering: "
         "expect ~+110 GiB/dev -> refuted as a memory-feasible single-pod "
         "config; the right home for it is EP over more pods).",
         dict(rules_override={"param_embed": None},
              opt_kw={"moment_dtype": "bfloat16"}, scan_only=True)),
        ("einsum_dispatch", "Counterfactual: naive one-hot einsum dispatch "
         "instead of shard_map EP. Expect compute term to explode "
         "(O(T*E*C*d) extra matmul flops) — the refutation control.",
         dict(overrides={"moe_dispatch": "einsum"},
              opt_kw={"moment_dtype": "bfloat16"})),
        ("remat_dots", "dots-remat instead of full: fewer recompute flops "
         "(compute term down ~25%) for more live memory.",
         dict(overrides={"remat": "dots"},
              opt_kw={"moment_dtype": "bfloat16"})),
    ],
    # ---------------- llama3.2-3b decode (memory-bound serving) ---------
    "llama_decode": [
        ("baseline", "Baseline decode_32k: batch over data axis, KV len "
         "unsharded, kv_heads unshardable (8 < 16-way model axis) => "
         "attention reads replicated over the model axis; expect "
         "memory-dominated with poor useful ratio.",
         dict()),
        ("kv_seq_over_model", "Shard the KV-cache length dim over the "
         "16-way model axis: each chip streams 1/16 of the cache per "
         "token; memory term should drop sharply; adds small softmax "
         "all-reduces.",
         dict(rules_override={"kv_seq": "model"})),
        ("no_fsdp", "Replicate weights over the data axis (weight-"
         "stationary serving): removes per-step param all-gathers; "
         "bytes/dev rises by params/16.",
         dict(fsdp=False, rules_override={"kv_seq": "model"})),
    ],
    # ---------------- gemma-7b train (compute/memory-bound dense) -------
    "gemma_train": [
        ("baseline", "Baseline train_4k with full remat: expect memory "
         "term dominated by S^2 attention scores (XLA path materialises "
         "them) and compute inflated ~4/3 by full-layer recompute.",
         dict()),
        ("remat_dots", "dots-remat: stop recomputing matmuls in bwd; "
         "compute term down ~25%, live bytes up.",
         dict(overrides={"remat": "dots"})),
        ("seq_over_model", "Sequence-parallel activations: shard the 4k "
         "sequence over the model axis between attention blocks "
         "(norm/mlp run on S/16 slices); HBM traffic per chip drops.",
         dict(rules_override={"seq": "model"})),
        ("batch_over_pod_data", "Also shard batch over 'model' for the "
         "score tensor via 2D (batch x heads) attention partitioning — "
         "counterfactual check; GSPMD may insert resharding.",
         dict(rules_override={"batch": ("data", "model")})),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(EXPERIMENTS), required=False)
    ap.add_argument("--step", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list or not args.cell:
        for cell, steps in EXPERIMENTS.items():
            print(cell)
            for name, hyp, _kw in steps:
                print(f"  {name}: {hyp[:90]}...")
        return

    from repro.launch.dryrun import run_cell

    cell_arch = {
        "kimi_train": ("kimi-k2-1t-a32b", "train_4k"),
        "llama_decode": ("llama3.2-3b", "decode_32k"),
        "gemma_train": ("gemma-7b", "train_4k"),
    }[args.cell]

    log = []
    if os.path.exists(LOG):
        with open(LOG) as f:
            log = json.load(f)

    for name, hypothesis, kw in EXPERIMENTS[args.cell]:
        if args.step and name != args.step:
            continue
        key = f"{args.cell}/{name}"
        if any(e["key"] == key for e in log):
            print(f"SKIP {key} (already measured)")
            continue
        if name == "baseline":
            # the sweep's cached record IS the paper-faithful baseline
            cache = os.path.join(
                RESULTS, "dryrun",
                f"{cell_arch[0]}__{cell_arch[1]}__16x16__full.json")
            if os.path.exists(cache):
                with open(cache) as f:
                    rec = json.load(f)
                log.append({"key": key, "hypothesis": hypothesis,
                            "record": rec, "wall_s": 0.0,
                            "from_sweep_cache": True})
                with open(LOG, "w") as f:
                    json.dump(log, f, indent=2)
                print(f"logged {key} (from sweep cache)")
                continue
        print(f"== {key} ==\nhypothesis: {hypothesis}")
        t0 = time.time()
        try:
            rec = run_cell(cell_arch[0], cell_arch[1],
                           overrides=dict(kw.get("overrides", {})),
                           fsdp=kw.get("fsdp", True),
                           rules_override=kw.get("rules_override"),
                           opt_kw=kw.get("opt_kw"),
                           dual_lowering=True,
                           scan_only=kw.get("scan_only", False))
            entry = {"key": key, "hypothesis": hypothesis, "record": rec,
                     "wall_s": time.time() - t0}
        except Exception as e:
            entry = {"key": key, "hypothesis": hypothesis,
                     "error": f"{type(e).__name__}: {e}",
                     "wall_s": time.time() - t0}
            print(f"FAILED: {entry['error']}")
        log.append(entry)
        with open(LOG, "w") as f:
            json.dump(log, f, indent=2)
        print(f"logged {key}")


if __name__ == "__main__":
    main()
