"""Trend and regression report over the perf-history ledger.

``tools/check_policy_budget.py --record`` appends every recorded
baseline as one JSON line to
``benchmarks/results/history/policy_time_n256.jsonl`` (stamps, the full
metric block with bootstrap-CI bounds, the compile/steady split).  The
baseline *file* is overwritten in place on each record, so the ledger is
the only place the trajectory survives: this tool renders it as a
per-metric trend table — first / best / last / last-over-best ratio and
a unicode sparkline — and can gate on it.

``--fail-threshold R`` exits 1 when any *timing* metric's latest value
exceeds its historical best by more than ``R``x (accuracy metrics use
the same check; CI bound and count columns are trend-only).  That turns
the ledger into a slow-moving regression guard complementary to the
per-run policy budget: the budget compares against the previous record,
the ledger catches a boiled-frog drift across many records each of
which individually passed.

Ledger lines that fail to parse (or aren't dicts with a ``metrics``
block) are skipped with a notice, never fatal — an append-only file
interrupted mid-line must not brick the report.

Examples::

    python tools/perf_history.py
    python tools/perf_history.py --fail-threshold 2.0
    python tools/perf_history.py path/to/other_ledger.jsonl --metric acc_open_mape
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

DEFAULT_LEDGER = os.path.join(_ROOT, "benchmarks", "results", "history",
                              "policy_time_n256.jsonl")

#: Metric suffixes excluded from the trend/gate table: interval bounds
#: and counts ride along with their parent metric.
_SKIP_SUFFIXES = ("_ci_lo", "_ci_hi")


def load_ledger(path: str) -> List[Dict]:
    """Parse the ledger; bad lines are skipped with a notice."""
    if not os.path.exists(path):
        return []
    rows: List[Dict] = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except Exception:
                print(f"# skipping unparsable ledger line {ln}",
                      file=sys.stderr)
                continue
            if not isinstance(obj, dict) or "metrics" not in obj:
                print(f"# skipping non-export ledger line {ln}",
                      file=sys.stderr)
                continue
            rows.append(obj)
    return rows


def _is_timing(key: str) -> bool:
    return key.endswith(("_us", "_ms", "_s", "_x")) or "_us_" in key


def _gated(key: str) -> bool:
    """Timing and accuracy metrics gate; bounds/counts are trend-only."""
    if key.endswith(_SKIP_SUFFIXES):
        return False
    return _is_timing(key) or key.startswith("acc_")


def trend_table(rows: List[Dict],
                only: Optional[str] = None) -> List[Dict]:
    """Per-metric trend rows: series, first/best/last, last/best ratio.

    ``best`` is the minimum — every ledger metric (wall time, MAPE,
    compile cost) improves downward.  Metrics missing from some records
    trend over the records that carry them.
    """
    keys: List[str] = []
    for r in rows:
        for k in r["metrics"]:
            if k not in keys and not k.endswith(_SKIP_SUFFIXES):
                keys.append(k)
    out = []
    for k in keys:
        if only and k != only:
            continue
        series = [float(r["metrics"][k]) for r in rows
                  if k in r["metrics"]]
        if not series:
            continue
        best = min(series)
        out.append({
            "metric": k,
            "series": series,
            "first": series[0],
            "best": best,
            "last": series[-1],
            "ratio": (series[-1] / best) if best else float("inf"),
            "gated": _gated(k),
        })
    return out


def render(rows: List[Dict], table: List[Dict],
           threshold: Optional[float]) -> int:
    """Print the trend report; count of threshold breaches returned."""
    from tools.obs_report import sparkline

    first_t = rows[0].get("recorded_unix", 0)
    last_t = rows[-1].get("recorded_unix", 0)
    span_days = max(0.0, (last_t - first_t) / 86400.0)
    print(f"perf history: {len(rows)} record(s) over {span_days:.1f} "
          f"day(s), last recorded "
          + time.strftime("%Y-%m-%d %H:%M:%S", time.localtime(last_t)))
    width = max(len(t["metric"]) for t in table)
    breaches = 0
    for t in table:
        verdict = ""
        if threshold is not None and t["gated"]:
            if t["ratio"] > threshold:
                verdict = f"  REGRESSION > {threshold:.2f}x best"
                breaches += 1
            else:
                verdict = "  OK"
        print(
            f"  {t['metric']:<{width}}  "
            f"first {t['first']:>10.4g}  best {t['best']:>10.4g}  "
            f"last {t['last']:>10.4g}  ({t['ratio']:>5.2f}x best)  "
            f"{sparkline(t['series'], width=24)}{verdict}"
        )
    return breaches


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("ledger", nargs="?", default=DEFAULT_LEDGER,
                    help="ledger .jsonl (default: the policy-budget one)")
    ap.add_argument("--metric", default=None,
                    help="trend a single metric instead of all")
    ap.add_argument("--fail-threshold", type=float, default=None,
                    help="exit 1 when any gated metric's last value "
                         "exceeds its historical best by this ratio")
    args = ap.parse_args(argv)

    rows = load_ledger(args.ledger)
    if not rows:
        print(f"perf_history: no usable records in {args.ledger}",
              file=sys.stderr)
        return 1
    table = trend_table(rows, only=args.metric)
    if not table:
        print(f"perf_history: metric {args.metric!r} not in the ledger",
              file=sys.stderr)
        return 1
    breaches = render(rows, table, args.fail_threshold)
    if breaches:
        print(f"perf_history: {breaches} metric(s) regressed past the "
              "threshold", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
