"""Render and diff ``repro.obs`` run exports.

Report mode renders one stamped run export (the
:mod:`repro.obs.metrics` schema) as a terminal report: the stamp
header, the metrics table, unicode sparklines for every recorded
timeline and telemetry counter, and a span-name wall-time breakdown
when the export carries trace events.

Diff mode (``--diff A B``) compares two exports metric by metric with
noise-aware thresholds: wall-time metrics (``*_us``/``*_ms``/``*_s``
suffixes, and ``*_x`` overhead ratios) are jittery on a shared dev
box, so they get a ratio budget (default 2.0x, ``--time-budget``);
everything else — counters, slowdowns, IPC — is deterministic given
the stamps, so it gets a tight relative tolerance (default 5%,
``--rel``).  Exits 1 when any metric breaches its threshold, so the
smoke tier can pin a benchmark run against its recorded baseline.

Both modes refuse exports whose schema or RNG stream stamps do not
match the current code (``repro.obs.metrics.load_run``) — a report
over a stale recording would compare incomparable numbers.  Diffing a
lane-batched export (``batched`` stamp, ``repro.online.batch_sim``)
against a single-lane one — or two batched exports at different lane
counts — is refused for the same reason: per-scenario timings under
the two measurement protocols are different quantities.

Lane-batched exports may carry a ``lane_metrics`` block
(``{metric: {mean, lo, hi, n}}``): report mode renders it as
mean ± CI columns, and diff mode treats overlapping intervals as
agreement — a seed-resampled re-measurement whose CI covers the
baseline's is not a drift, however the point means wiggle.

Examples::

    python tools/obs_report.py benchmarks/results/obs_smoke_baseline.json
    python tools/obs_report.py --diff base.json new.json --time-budget 2.0
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

_SPARK = "▁▂▃▄▅▆▇█"

#: Metric-name suffixes of the fault/resilience counters
#: (``OnlineStats.summary`` under a FaultProfile; arm prefixes allowed).
#: Exports carrying any of them get a dedicated report block.
_FAULT_METRICS = (
    "n_evicted", "n_requeued", "n_dropped", "n_retry_waiting",
    "n_in_flight", "total_failures", "total_recoveries",
    "straggling_core_quanta", "mean_retries_completed",
)


def sparkline(values, width: int = 48) -> str:
    """Downsample a series to ``width`` buckets of unicode bars."""
    v = np.asarray(values, np.float64)
    v = v[np.isfinite(v)]
    if v.size == 0:
        return "(empty)"
    if v.size > width:
        edges = np.linspace(0, v.size, width + 1).astype(int)
        v = np.array([v[a:b].mean() for a, b in zip(edges[:-1], edges[1:])])
    lo, hi = float(v.min()), float(v.max())
    if hi <= lo:
        return _SPARK[0] * v.size
    idx = ((v - lo) / (hi - lo) * (len(_SPARK) - 1)).round().astype(int)
    return "".join(_SPARK[i] for i in idx)


def _is_timing(key: str) -> bool:
    """Wall-time (or wall-time-ratio) metrics get the jitter budget."""
    return key.endswith(("_us", "_ms", "_s", "_x")) or "_us_" in key


def span_breakdown(spans: List[Dict]) -> List[Tuple[str, float, int]]:
    """``(name, total_ms, count)`` rows from chrome trace events."""
    acc: Dict[str, List[float]] = {}
    for ev in spans:
        if ev.get("ph") != "X":
            continue
        acc.setdefault(ev["name"], []).append(float(ev.get("dur", 0.0)))
    rows = [(name, sum(ds) / 1e3, len(ds)) for name, ds in acc.items()]
    return sorted(rows, key=lambda r: -r[1])


def render(run: Dict) -> str:
    out: List[str] = []
    stamp = time.strftime(
        "%Y-%m-%d %H:%M:%S", time.localtime(run.get("recorded_unix", 0))
    )
    out.append(f"run: {run.get('name', '?')}")
    out.append(
        f"  schema v{run.get('obs_schema_version')}  "
        f"rng v{run.get('rng_stream_version')}"
        + (f"  scan v{run['scan_rng_stream_version']}"
           if "scan_rng_stream_version" in run else "")
        + (f"  fault v{run['fault_rng_stream_version']}"
           if "fault_rng_stream_version" in run else "")
        + (f"  engine={run['engine']}" if "engine" in run else "")
        + (f"  batched lanes={run.get('lanes', '?')}"
           if run.get("batched") else "")
        + f"  recorded {stamp}"
    )
    out.append("")
    out.append("metrics:")
    width = max((len(k) for k in run["metrics"]), default=0)
    for k, v in run["metrics"].items():
        out.append(f"  {k:<{width}}  {v:>14.6g}")
    lane_metrics = run.get("lane_metrics") or {}
    if lane_metrics:
        out.append("")
        out.append("lane metrics (cross-lane mean ± bootstrap CI):")
        lw = max(len(k) for k in lane_metrics)
        for k, v in lane_metrics.items():
            out.append(
                f"  {k:<{lw}}  {v['mean']:>12.6g}  "
                f"[{v['lo']:.6g}, {v['hi']:.6g}]  n={v.get('n', '?')}"
            )
    fault_rows = [
        (k, v) for k, v in run["metrics"].items()
        if any(k.endswith(suffix) for suffix in _FAULT_METRICS)
    ]
    if fault_rows:
        out.append("")
        out.append("resilience (fault-injection counters):")
        for k, v in fault_rows:
            out.append(f"  {k:<{width}}  {v:>14.6g}")
    for arm, tl in (run.get("timelines") or {}).items():
        out.append("")
        out.append(f"timeline {arm} ({len(tl)} quanta, "
                   f"min {min(tl):.3g} max {max(tl):.3g}):")
        out.append(f"  {sparkline(tl)}")
    for arm, payload in (run.get("telemetry") or {}).items():
        from repro.obs.telemetry import TelemetryLog

        log = TelemetryLog.from_dict(payload)
        out.append("")
        out.append(f"telemetry[{arm}] policy={log.policy!r} "
                   f"({log.quanta} quanta x {len(log.fields)} counters):")
        fw = max(len(f) for f in log.fields)
        for f in log.fields:
            col = log.timeline(f)
            out.append(
                f"  {f:<{fw}}  {sparkline(col, width=32)}  "
                f"mean {col.mean():>10.4g}  max {col.max():>10.4g}"
            )
    for arm, rep in (run.get("accuracy") or {}).items():
        out.extend(_accuracy_panel(arm, rep))
    spans = run.get("spans") or []
    if spans:
        rows = span_breakdown(spans)
        out.append("")
        out.append(f"spans ({len(spans)} events):")
        nw = max(len(r[0]) for r in rows)
        for name, total_ms, count in rows:
            out.append(f"  {name:<{nw}}  {total_ms:>10.2f} ms  "
                       f"x{count}")
    return "\n".join(out)


def _accuracy_panel(arm: str, rep: Dict, top: int = 10) -> List[str]:
    """Per-app accuracy panel rows for one arm's accuracy report
    (``repro.obs.accuracy.accuracy_report``): the overall MAPE/bias
    stack, the worst-``top`` per-app rows, the error-CCDF tail and the
    drift-window verdict."""
    out: List[str] = [""]
    ov = rep.get("overall", {})
    out.append(
        f"accuracy[{arm}] policy={rep.get('policy', '')!r}: "
        f"MAPE {ov.get('mape', 0.0):.2%}  bias {ov.get('bias', 0.0):+.2%}"
        f"  rmse {ov.get('rmse', 0.0):.4g}  n={ov.get('n', 0)}"
    )
    per_app = rep.get("per_app") or {}
    if per_app:
        rows = sorted(per_app.items(), key=lambda kv: -kv[1]["mape"])
        shown = rows[:top]
        aw = max(len(k) for k, _ in shown)
        out.append(f"  per-app (worst {len(shown)} of {len(rows)}):")
        for name, st in shown:
            out.append(
                f"    app {name:<{aw}}  MAPE {st['mape']:>7.2%}  "
                f"bias {st['bias']:>+8.2%}  n={st['n']}"
            )
    ccdf = rep.get("ccdf") or {}
    if ccdf.get("grid"):
        tail = "  ".join(
            f">{g:.0%}:{p:.2f}"
            for g, p in zip(ccdf["grid"], ccdf["p_gt"])
        )
        out.append(f"  |rel err| CCDF  {tail}")
    drift = rep.get("drift") or {}
    if drift.get("mape") is not None:
        flagged = drift.get("flagged", [])
        verdict = (f"DRIFT in windows {flagged}" if flagged
                   else "no drift")
        out.append(
            f"  drift (window={drift.get('window')}, budget "
            f"{drift.get('budget', 0.0):.2%}): "
            f"{sparkline(drift['mape'], width=32)}  {verdict}"
        )
    return out


def _protocol_mismatch(base: Dict, new: Dict) -> Optional[str]:
    """Why two exports must not be diffed, or None when they may.

    Batched and single-lane recordings measure per-scenario cost under
    different protocols (whole-grid share vs single-dispatch median);
    two batched recordings at different lane counts likewise.  Exports
    at different schema versions are refused too: old-schema baselines
    stay *readable* (render, trend) but a cross-schema diff would
    compare runs whose recorded surface differs — re-record the
    baseline under the current schema instead."""
    b_schema = base.get("obs_schema_version")
    n_schema = new.get("obs_schema_version")
    if b_schema != n_schema:
        return (f"schema versions differ (v{b_schema} vs v{n_schema}) — "
                "old exports are readable but not diffable")
    b_batched = bool(base.get("batched", False))
    n_batched = bool(new.get("batched", False))
    if b_batched != n_batched:
        bb = "batched" if b_batched else "single-lane"
        nn = "batched" if n_batched else "single-lane"
        return (f"base is {bb}, new is {nn} — per-scenario timings are "
                "not comparable across the two measurement protocols")
    if b_batched and base.get("lanes") != new.get("lanes"):
        return (f"lane counts differ ({base.get('lanes')} vs "
                f"{new.get('lanes')}) — the whole-grid wall is shared "
                "over a different number of scenarios")
    return None


def _ci_of(run: Dict, key: str) -> Optional[Tuple[float, float]]:
    """The [lo, hi] interval a run carries for ``key``, if any — from
    ``lane_metrics`` or from ``<key>_ci_lo``/``_ci_hi`` metric rows."""
    lm = (run.get("lane_metrics") or {}).get(key)
    if lm is not None:
        return float(lm["lo"]), float(lm["hi"])
    m = run["metrics"]
    if key + "_ci_lo" in m and key + "_ci_hi" in m:
        return float(m[key + "_ci_lo"]), float(m[key + "_ci_hi"])
    return None


def diff(base: Dict, new: Dict, time_budget: float, rel: float) -> int:
    """Print a metric-by-metric comparison; count of breaches returned."""
    bm, nm = base["metrics"], new["metrics"]
    keys = sorted(set(bm) | set(nm))
    width = max((len(k) for k in keys), default=0)
    breaches = 0
    print(f"diff: {base.get('name', '?')} (base) vs "
          f"{new.get('name', '?')} (new)")
    for k in keys:
        if k not in bm or k not in nm:
            side = "base" if k in bm else "new"
            print(f"  {k:<{width}}  only in {side}")
            continue
        b, n = float(bm[k]), float(nm[k])
        if _is_timing(k):
            # Wall times: noise-aware ratio budget, one-sided (faster
            # is never a breach).
            ratio = n / b if b else float("inf")
            ok = (n <= b * time_budget) or (n == b)
            verdict = "OK" if ok else f"SLOWER than {time_budget:.2f}x"
            print(f"  {k:<{width}}  {b:>12.5g} -> {n:>12.5g}  "
                  f"({ratio:>6.2f}x)  {verdict}")
        else:
            denom = max(abs(b), 1e-12)
            delta = abs(n - b) / denom
            ok = delta <= rel
            verdict = "OK" if ok else f"DRIFT > {rel:.0%}"
            if not ok:
                # Interval-aware second chance: when both sides carry a
                # CI for this metric and the intervals overlap, the
                # drift is within seed-resampling noise.
                bci, nci = _ci_of(base, k), _ci_of(new, k)
                if bci and nci and not (nci[1] < bci[0] or nci[0] > bci[1]):
                    ok, verdict = True, "OK (CI overlap)"
            print(f"  {k:<{width}}  {b:>12.5g} -> {n:>12.5g}  "
                  f"({delta:>6.2%})  {verdict}")
        breaches += 0 if ok else 1
    return breaches


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+",
                    help="one export to render, or two with --diff")
    ap.add_argument("--diff", action="store_true",
                    help="compare two exports (base new) instead of "
                         "rendering one")
    ap.add_argument("--time-budget", type=float, default=2.0,
                    help="allowed slowdown ratio for wall-time metrics")
    ap.add_argument("--rel", type=float, default=0.05,
                    help="relative tolerance for non-timing metrics")
    args = ap.parse_args(argv)

    from repro.obs import metrics as obs_metrics

    runs = []
    for path in args.paths:
        run = obs_metrics.load_run(path)
        if run is None:
            print(f"obs_report: no usable run export at {path} (missing, "
                  "unreadable or stale-stamped)", file=sys.stderr)
            return 1
        runs.append(run)

    if args.diff:
        if len(runs) != 2:
            print("obs_report: --diff needs exactly two exports",
                  file=sys.stderr)
            return 1
        why = _protocol_mismatch(runs[0], runs[1])
        if why:
            print(f"obs_report: refusing diff: {why}; re-record one side "
                  "under the other's protocol", file=sys.stderr)
            return 1
        breaches = diff(runs[0], runs[1], args.time_budget, args.rel)
        if breaches:
            print(f"obs_report: {breaches} metric(s) breached their "
                  "thresholds", file=sys.stderr)
            return 1
        print("obs_report: all metrics within thresholds")
        return 0

    for run in runs:
        print(render(run))
    return 0


if __name__ == "__main__":
    sys.exit(main())
