#!/usr/bin/env bash
# Sub-minute sanity run of the benchmark entry points (--smoke modes) plus
# the N=256 policy-time regression guard.  Wired into the test suite
# (tests/test_bench_smoke.py, marked `slow`) so the benchmarks cannot rot
# — and the fused SYNPA hot path cannot quietly regress — without tier-1
# noticing.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
python benchmarks/online_churn.py --smoke
python benchmarks/online_churn.py --smoke --engine scan
python benchmarks/cluster_scale.py --smoke
python benchmarks/cluster_scale.py --smoke --engine scan
python tools/check_policy_budget.py
