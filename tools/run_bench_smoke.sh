#!/usr/bin/env bash
# Sub-minute sanity run of the benchmark entry points (--smoke modes) plus
# the N=256 policy-time regression guard.  Wired into the test suite
# (tests/test_bench_smoke.py, marked `slow`) so the benchmarks cannot rot
# — and the fused SYNPA hot path cannot quietly regress — without tier-1
# noticing.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
python benchmarks/online_churn.py --smoke
python benchmarks/online_churn.py --smoke --engine scan
# Fault-injection arm: the graceful-degradation sweep on the one-dispatch
# engine — exercises eviction/requeue, stragglers and the degradation
# headline end to end (results are not recorded under --smoke).
python benchmarks/online_churn.py --smoke --engine scan --faults
# Batched-scenario arm: a tiny rho x admission x seed grid as ONE
# vmap-batched, transfer-guarded dispatch, asserted f32-bit-identical
# lane by lane against the sequential dispatches it replaces
# (repro.online.batch_sim; unrecorded under --smoke).
python benchmarks/online_churn.py --smoke --batched --seeds 2
python benchmarks/cluster_scale.py --smoke
python benchmarks/cluster_scale.py --smoke --engine scan
# Telemetry + accuracy arm: run both engines with the device ring, the
# per-app rings and span tracing on, render the run report (per-app
# MAPE/drift panels included), and diff it against the recorded
# baseline.  The deterministic metrics — including the per-app accuracy
# scalars (open_acc_mape etc.), so a prediction-error regression fails
# the smoke — get the tight 5% tolerance; wall-time metrics get 4x here
# (single-shot run on a jittery box — check_policy_budget below guards
# timing properly, best-of-two, plus its own noise-aware accuracy arm).
# The live export lands in the untracked results/smoke/ directory so a
# smoke run leaves the working tree clean.
python benchmarks/obs_smoke.py --smoke
python tools/obs_report.py benchmarks/results/smoke/obs_smoke.json > /dev/null
python tools/obs_report.py --diff \
    benchmarks/results/obs_smoke_baseline.json \
    benchmarks/results/smoke/obs_smoke.json --time-budget 4.0
python tools/check_policy_budget.py
