#!/usr/bin/env bash
# Sub-minute sanity run of the benchmark entry points (--smoke modes).
# Wired into the test suite (tests/test_bench_smoke.py, marked `slow`) so
# the benchmarks cannot rot without tier-1 noticing.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
python benchmarks/online_churn.py --smoke
python benchmarks/cluster_scale.py --smoke
