"""Calibration driver: characterise apps, fit models, race the policies.

Run:  PYTHONPATH=src python tools/calibrate.py [--quick]
"""

import argparse
import time

import numpy as np

from repro.core import isc
from repro.core.baselines import (
    HySchedScheduler,
    LinuxScheduler,
    OracleScheduler,
    RandomStaticScheduler,
)
from repro.core.synpa import SynpaScheduler
from repro.smt import machine as mc
from repro.smt import metrics, training, workloads
from repro.smt.apps import APP_PROFILES, pool_profiles

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--workloads", type=str, default="")
    args = ap.parse_args()

    m = mc.SMTMachine(mc.MachineParams(), seed=0)

    # --- Figure 2 sanity: stack heights ---
    print("== Fig2: measured stack heights (raw) ==")
    lt, gt = 0, 0
    for p in APP_PROFILES:
        samples, _ = m.run_solo(p, 20, noisy=False)
        c = np.array([s.as_tuple() for s in samples])
        raw = np.asarray(isc.raw_stack(c[:, 0], c[:, 1], c[:, 2], c[:, 3])).mean(0)
        h = raw[:3].sum()
        flag = "GT100" if h > 1.0 else "LT100"
        if h > 1.0: gt += 1
        else: lt += 1
        print(f"  {p.name:14s} h={h:6.3f} {flag}  DI={raw[0]:.3f} FE={raw[1]:.3f} BE={raw[2]:.3f}")
    print(f"  LT100: {lt}, GT100: {gt}  (paper: 21 / 7)")

    # --- classification ---
    groups = workloads.classify(m)
    from collections import Counter
    print("== groups ==", Counter(groups.values()))
    for g in ("frontend", "backend", "others"):
        print(f"  {g}: {[n for n,v in groups.items() if v==g]}")

    # --- model fit ---
    t0 = time.time()
    models, data = training.build_all_models(
        m, solo_quanta=40 if args.quick else 60,
        pair_quanta=8 if args.quick else 12,
    )
    print(f"== models fit in {time.time()-t0:.1f}s ==")
    for name, model in models.items():
        mse = np.asarray(model.mse)[: model.n_categories]
        print(f"  {name:14s} MSE={np.array2string(mse, precision=4)}")
        print(f"    coeffs=\n{np.array2string(np.asarray(model.coeffs)[:model.n_categories], precision=4)}")

    # --- race on workloads ---
    wls = workloads.make_workloads(m)
    names = args.workloads.split(",") if args.workloads else (
        ["fb0", "fb1", "be0", "fe0"] if args.quick else list(wls)
    )
    policies = {
        "linux": lambda: LinuxScheduler(),
        "hy-sched": lambda: HySchedScheduler(),
        "SYNPA3_N": lambda: SynpaScheduler(isc.SYNPA3_N, models["SYNPA3_N"]),
        "SYNPA4_N": lambda: SynpaScheduler(isc.SYNPA4_N, models["SYNPA4_N"]),
        "SYNPA4_R-FEBE": lambda: SynpaScheduler(isc.SYNPA4_R_FEBE, models["SYNPA4_R-FEBE"]),
        "oracle": lambda: OracleScheduler(),
    }
    agg = {p: {"tt": [], "ipc": []} for p in policies}
    t0 = time.time()
    for w in names:
        profs = workloads.workload_profiles(wls[w])
        base = None
        row = [w]
        for pname, factory in policies.items():
            st = metrics.run_repeated(m, profs, factory, repeats=args.repeats, base_seed=hash(w) % 10000)
            if pname == "linux":
                base = st
            sp = metrics.speedup(base.makespan_s, st.makespan_s)
            spi = metrics.speedup(st.ipc_geomean, base.ipc_geomean)  # inverse: ipc ratio
            agg[pname]["tt"].append(sp)
            agg[pname]["ipc"].append(st.ipc_geomean / base.ipc_geomean)
            row.append(f"{pname}:TTx{sp:.3f}/IPCx{st.ipc_geomean/base.ipc_geomean:.3f}")
        print("  ".join(row))
    print(f"== raced in {time.time()-t0:.1f}s ==")
    print("== averages (TT speedup vs linux | IPC ratio) ==")
    for pname in policies:
        tt = np.array(agg[pname]["tt"]); ipc = np.array(agg[pname]["ipc"])
        mixed = [i for i, w in enumerate(names) if w.startswith("fb")]
        mtt = tt[mixed].mean() if mixed else float("nan")
        print(f"  {pname:14s} TT {tt.mean():.3f} (mixed {mtt:.3f}) | IPC {ipc.mean():.3f}")

if __name__ == "__main__":
    main()
